//! Multi-erasure repair planning (DESIGN.md §4): per-stripe plans when a
//! scenario loses *several* blocks of the same stripe — concurrent node
//! failures, whole-rack failures (paper §6 only evaluates single-node
//! failures; the Facebook warehouse study, arXiv:1309.0186, shows
//! correlated multi-failures dominate real repair traffic).
//!
//! Strategy per stripe:
//! * exactly one lost block → the policy's native single-erasure plan
//!   ([`plan_repair`]), which preserves D³'s cross-rack-minimal inner-rack
//!   aggregation (§5.1);
//! * ≥ 2 lost blocks, RS → full decode: the k smallest surviving blocks
//!   ship whole to a per-block recovery target (RS decode with multiple
//!   erasures is just decode over a survivor set excluding every erasure);
//! * ≥ 2 lost blocks, LRC → local-then-global escalation: a block whose
//!   typed minimal repair set (§5.2) is fully alive keeps the local plan;
//!   otherwise its generator row is expressed in the span of the surviving
//!   rows ([`express_in_rows`]) and the nonzero-coefficient survivors
//!   become the sources.
//!
//! Recovery targets come from the policy where its single-failure case
//! analysis is valid; when the designated target is itself failed, or two
//! lost blocks of one stripe would collide, a deterministic fallback scan
//! reassigns targets while keeping the placement invariants (no failed
//! node, no node reuse within the stripe, rack limit).

use std::collections::HashSet;

use anyhow::{bail, Result};

use crate::codes::{CodeSpec, LrcCode, RsCode};
use crate::gf;
use crate::gf::matrix::express_in_rows;
use crate::placement::{Placement, StripePlacement};
use crate::topology::{ClusterSpec, Location};
use crate::util::rng::splitmix64;

use super::plan::{plan_coefficients, plan_repair, RepairPlan};

/// Repair plans for every block lost to `failed` among stripes
/// `0..stripes`, ordered by stripe id. Generalizes
/// [`super::node_recovery_plans`] to arbitrary failure sets (K concurrent
/// nodes, a whole rack); bails if some stripe is unrecoverable.
pub fn scenario_recovery_plans(
    policy: &dyn Placement,
    stripes: u64,
    failed: &[Location],
    seed: u64,
) -> Result<Vec<RepairPlan>> {
    let failed_set: HashSet<Location> = failed.iter().copied().collect();
    let len = policy.code().len();
    let mut plans = Vec::new();
    for sid in 0..stripes {
        // Alloc-free miss path: most stripes lose nothing, so probe block
        // locations one at a time and only plan (which materializes the
        // full stripe) on a hit.
        let lost: Vec<usize> = (0..len)
            .filter(|&b| failed_set.contains(&policy.block_at(sid, b)))
            .collect();
        if lost.is_empty() {
            continue;
        }
        plans.extend(stripe_repair_plans(policy, sid, &lost, &failed_set, seed)?);
    }
    Ok(plans)
}

/// Plans for one stripe with `lost` erased blocks (ascending indices).
pub fn stripe_repair_plans(
    policy: &dyn Placement,
    sid: u64,
    lost: &[usize],
    failed_set: &HashSet<Location>,
    seed: u64,
) -> Result<Vec<RepairPlan>> {
    assert!(!lost.is_empty(), "stripe_repair_plans with no losses");
    let sp = policy.stripe(sid);
    let code = policy.code();
    let cluster = policy.cluster();
    let lost_set: HashSet<usize> = lost.iter().copied().collect();

    if lost.len() == 1 {
        // Single erasure: the policy's native plan keeps D³'s minimal
        // cross-rack aggregation. Only the target may need rerouting (it
        // can land on another failed node in multi-node scenarios).
        let mut plan = plan_repair(policy, sid, lost[0], seed);
        if failed_set.contains(&plan.writer) {
            let tgt = pick_target(
                &cluster, &sp, &lost_set, &[], failed_set, code.rack_limit(), seed, sid, lost[0],
            );
            let Some(tgt) = tgt else {
                bail!("stripe {sid}: no valid recovery target for block {}", lost[0]);
            };
            plan.compute_at = tgt;
            plan.writer = tgt;
        }
        return Ok(vec![plan]);
    }

    // Multi-erasure: full decode (RS) or local-then-global escalation (LRC).
    let survivors: Vec<usize> =
        (0..sp.locs.len()).filter(|b| !lost_set.contains(b)).collect();
    let mut taken: Vec<Location> = Vec::new();
    let mut out = Vec::with_capacity(lost.len());
    for &block in lost {
        let (sources, coeffs): (Vec<usize>, Vec<u8>) = match code {
            CodeSpec::Rs { k, m } => {
                if survivors.len() < k {
                    bail!(
                        "stripe {sid}: {} survivors < k = {k} — unrecoverable",
                        survivors.len()
                    );
                }
                let srcs: Vec<usize> = survivors.iter().copied().take(k).collect();
                let rs = RsCode::new(k, m);
                let cs = rs
                    .decode_coeffs(&srcs, block)
                    .expect("k distinct survivors excluding the target");
                (srcs, cs)
            }
            CodeSpec::Lrc { k, l, g } => {
                let lrc = LrcCode::new(k, l, g);
                let (min_src, min_coeffs) = lrc.repair_plan(block);
                if min_src.iter().all(|s| !lost_set.contains(s)) {
                    // local repair still possible despite the other losses
                    (min_src, min_coeffs)
                } else {
                    // global escalation over the surviving generator rows
                    let rows: Vec<&[u8]> =
                        survivors.iter().map(|&s| lrc.generator_row(s)).collect();
                    let Some(all) = express_in_rows(&rows, lrc.generator_row(block)) else {
                        bail!(
                            "stripe {sid}: block {block} undecodable under {} erasures",
                            lost.len()
                        );
                    };
                    let mut srcs = Vec::new();
                    let mut cs = Vec::new();
                    for (i, &s) in survivors.iter().enumerate() {
                        if all[i] != 0 {
                            srcs.push(s);
                            cs.push(all[i]);
                        }
                    }
                    (srcs, cs)
                }
            }
        };
        let target = pick_target(
            &cluster, &sp, &lost_set, &taken, failed_set, code.rack_limit(), seed, sid, block,
        );
        let Some(target) = target else {
            bail!("stripe {sid}: no valid recovery target for block {block}");
        };
        taken.push(target);
        let direct: Vec<(usize, Location)> =
            sources.iter().map(|&b| (b, sp.locs[b])).collect();
        out.push(RepairPlan {
            stripe: sid,
            failed_block: block,
            compute_at: target,
            writer: target,
            persist: true,
            aggregations: Vec::new(),
            direct,
            coeffs: Some(coeffs),
        });
    }
    Ok(out)
}

/// Numerically execute a plan over in-memory stripe shards (`shards[b]` =
/// bytes of block `b`): stage the inner-rack aggregations exactly as the
/// chunked executor does — one fused cache-blocked multiply-accumulate
/// per aggregation group ([`gf::combine_many_into`]), a SWAR XOR merge of
/// each partial, and one fused combine over the direct sources. This is
/// the network-free twin of the cluster data path, used by the property
/// suite and the round-trip tests below.
pub fn execute_plan_bytes(
    code: &CodeSpec,
    plan: &RepairPlan,
    shards: &[Vec<u8>],
) -> Vec<u8> {
    let sources = plan.source_blocks();
    let coeffs = plan_coefficients(code, plan);
    debug_assert_eq!(sources.len(), coeffs.len());
    let coeff_of =
        |b: usize| coeffs[sources.binary_search(&b).expect("source present")];
    let width = sources.first().map_or(0, |&b| shards[b].len());
    let mut acc = vec![0u8; width];
    for agg in &plan.aggregations {
        let mut partial = vec![0u8; width];
        let pairs: Vec<(u8, &[u8])> = agg
            .inputs
            .iter()
            .map(|&(b, _)| (coeff_of(b), shards[b].as_slice()))
            .collect();
        gf::combine_many_into(&mut partial, &pairs);
        gf::xor_into(&mut acc, &partial);
    }
    let pairs: Vec<(u8, &[u8])> = plan
        .direct
        .iter()
        .map(|&(b, _)| (coeff_of(b), shards[b].as_slice()))
        .collect();
    gf::combine_many_into(&mut acc, &pairs);
    acc
}

/// Deterministic fallback target: scan the cluster from a (sid, block)-keyed
/// start offset for a node that is alive, unused by the stripe's surviving
/// blocks, not already assigned to another recovered block of this stripe,
/// and whose rack stays within the code's rack limit. The limit is relaxed
/// (never the node constraints) if the cluster is too tight to honor it.
#[allow(clippy::too_many_arguments)]
fn pick_target(
    cluster: &ClusterSpec,
    sp: &StripePlacement,
    lost_set: &HashSet<usize>,
    taken: &[Location],
    failed_set: &HashSet<Location>,
    rack_limit: usize,
    seed: u64,
    sid: u64,
    block: usize,
) -> Option<Location> {
    let mut rack_count = vec![0usize; cluster.racks];
    for (bi, l) in sp.locs.iter().enumerate() {
        if !lost_set.contains(&bi) {
            rack_count[l.rack as usize] += 1;
        }
    }
    for t in taken {
        rack_count[t.rack as usize] += 1;
    }
    let n = cluster.node_count();
    let mut h = seed ^ sid.wrapping_mul(0x9e3779b97f4a7c15) ^ (block as u64).rotate_left(17);
    let start = (splitmix64(&mut h) as usize) % n;
    let node_ok = |loc: Location| {
        !failed_set.contains(&loc)
            && !taken.contains(&loc)
            && !sp
                .locs
                .iter()
                .enumerate()
                .any(|(bi, l)| !lost_set.contains(&bi) && *l == loc)
    };
    for off in 0..n {
        let loc = cluster.unflat((start + off) % n);
        if node_ok(loc) && rack_count[loc.rack as usize] < rack_limit {
            return Some(loc);
        }
    }
    for off in 0..n {
        let loc = cluster.unflat((start + off) % n);
        if node_ok(loc) {
            return Some(loc);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf;
    use crate::placement::{D3LrcPlacement, D3Placement, RddPlacement};
    use crate::recovery::plan::plan_coefficients;
    use crate::topology::ClusterSpec;

    fn rand_shards(k: usize, len: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15) | 1;
        (0..k)
            .map(|_| {
                (0..len)
                    .map(|_| {
                        s ^= s << 13;
                        s ^= s >> 7;
                        s ^= s << 17;
                        (s >> 24) as u8
                    })
                    .collect()
            })
            .collect()
    }

    /// Encode a full stripe (data + parity) for `code`.
    fn stripe_bytes(code: &CodeSpec, seed: u64, len: usize) -> Vec<Vec<u8>> {
        let data = rand_shards(code.k(), len, seed);
        let refs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let parity = match *code {
            CodeSpec::Rs { k, m } => RsCode::new(k, m).encode(&refs),
            CodeSpec::Lrc { k, l, g } => LrcCode::new(k, l, g).encode(&refs),
        };
        let mut all = data;
        all.extend(parity);
        all
    }

    /// Execute a plan numerically through the staged
    /// [`execute_plan_bytes`] path, and cross-check it against the flat
    /// combine (aggregation splits are linear, so they must agree).
    fn execute(plan: &RepairPlan, code: &CodeSpec, all: &[Vec<u8>]) -> Vec<u8> {
        let staged = execute_plan_bytes(code, plan, all);
        let sources = plan.source_blocks();
        let coeffs = plan_coefficients(code, plan);
        assert_eq!(sources.len(), coeffs.len());
        let shards: Vec<&[u8]> = sources.iter().map(|&b| all[b].as_slice()).collect();
        assert_eq!(staged, gf::combine(&coeffs, &shards), "staged != flat combine");
        staged
    }

    #[test]
    fn rs_two_node_failures_round_trip() {
        let code = CodeSpec::Rs { k: 6, m: 3 };
        let cluster = ClusterSpec::new(8, 3);
        let p = D3Placement::new(code, cluster).unwrap();
        let failed = vec![Location::new(0, 0), Location::new(1, 1)];
        let stripes = 120u64;
        let plans = scenario_recovery_plans(&p, stripes, &failed, 7).unwrap();
        assert!(!plans.is_empty());
        let failed_set: HashSet<Location> = failed.iter().copied().collect();
        let mut covered = 0usize;
        for sid in 0..stripes {
            let sp = p.stripe(sid);
            let lost: Vec<usize> = (0..9)
                .filter(|&b| failed_set.contains(&sp.locs[b]))
                .collect();
            let here: Vec<&RepairPlan> =
                plans.iter().filter(|pl| pl.stripe == sid).collect();
            assert_eq!(here.len(), lost.len(), "sid={sid}");
            covered += here.len();
            let all = stripe_bytes(&code, sid, 64);
            for plan in here {
                // sources avoid every lost block and every failed node
                for &(b, loc) in &plan.direct {
                    assert!(!lost.contains(&b), "sid={sid}: reads a lost block");
                    assert!(!failed_set.contains(&loc));
                }
                assert!(!failed_set.contains(&plan.writer));
                let rebuilt = execute(plan, &code, &all);
                assert_eq!(rebuilt, all[plan.failed_block], "sid={sid}");
            }
        }
        assert_eq!(covered, plans.len());
    }

    #[test]
    fn rs_full_rack_failure_round_trip_and_invariants() {
        let code = CodeSpec::Rs { k: 6, m: 3 };
        let cluster = ClusterSpec::new(8, 3);
        let p = D3Placement::new(code, cluster).unwrap();
        let rack = 2u32;
        let failed: Vec<Location> =
            (0..3).map(|j| Location::new(rack as usize, j)).collect();
        let failed_set: HashSet<Location> = failed.iter().copied().collect();
        let stripes = 90u64;
        let plans = scenario_recovery_plans(&p, stripes, &failed, 3).unwrap();
        for sid in 0..stripes {
            let sp = p.stripe(sid);
            let lost: Vec<usize> =
                (0..9).filter(|&b| sp.locs[b].rack == rack).collect();
            let here: Vec<&RepairPlan> =
                plans.iter().filter(|pl| pl.stripe == sid).collect();
            assert_eq!(here.len(), lost.len(), "sid={sid}");
            if here.is_empty() {
                continue;
            }
            let all = stripe_bytes(&code, sid, 48);
            // post-recovery layout keeps the invariants: writers distinct,
            // alive, and the rack limit m holds over survivors + recovered
            let mut rack_count = std::collections::HashMap::new();
            for (bi, l) in sp.locs.iter().enumerate() {
                if !lost.contains(&bi) {
                    *rack_count.entry(l.rack).or_insert(0usize) += 1;
                }
            }
            let mut writers = HashSet::new();
            for plan in &here {
                assert!(!failed_set.contains(&plan.writer));
                assert!(writers.insert(plan.writer), "sid={sid}: writer collision");
                *rack_count.entry(plan.writer.rack).or_insert(0) += 1;
                assert_eq!(execute(plan, &code, &all), all[plan.failed_block]);
                if here.len() > 1 {
                    assert!(plan.aggregations.is_empty(), "multi-loss is full decode");
                    assert!(plan.coeffs.is_some());
                }
            }
            assert!(
                rack_count.values().all(|&c| c <= 3),
                "sid={sid}: rack limit violated: {rack_count:?}"
            );
        }
    }

    #[test]
    fn lrc_local_then_global_escalation() {
        // (6,2,2): losing two data blocks of one local group breaks the
        // local plans; the globals must step in.
        let code = CodeSpec::Lrc { k: 6, l: 2, g: 2 };
        let cluster = ClusterSpec::new(11, 4);
        let p = D3LrcPlacement::new(code, cluster).unwrap();
        let sid = 5u64;
        let sp = p.stripe(sid);
        let lost = vec![0usize, 1];
        let failed_set: HashSet<Location> =
            lost.iter().map(|&b| sp.locs[b]).collect();
        let plans = stripe_repair_plans(&p, sid, &lost, &failed_set, 0).unwrap();
        assert_eq!(plans.len(), 2);
        let all = stripe_bytes(&code, 42, 96);
        for plan in &plans {
            // both lost blocks sit in group 0, so neither minimal set
            // survives — both plans must be escalated (explicit coeffs)
            assert!(plan.coeffs.is_some(), "expected escalated plan");
            assert!(plan
                .source_blocks()
                .iter()
                .all(|s| !lost.contains(s)));
            assert_eq!(execute(plan, &code, &all), all[plan.failed_block]);
        }
    }

    #[test]
    fn lrc_keeps_local_plan_when_groups_unharmed() {
        // losing one block of each local group keeps both typed plans local
        let code = CodeSpec::Lrc { k: 6, l: 2, g: 2 };
        let cluster = ClusterSpec::new(11, 4);
        let p = D3LrcPlacement::new(code, cluster).unwrap();
        let sid = 9u64;
        let sp = p.stripe(sid);
        let lost = vec![0usize, 3]; // one per group (group size 3)
        let failed_set: HashSet<Location> =
            lost.iter().map(|&b| sp.locs[b]).collect();
        let plans = stripe_repair_plans(&p, sid, &lost, &failed_set, 0).unwrap();
        let all = stripe_bytes(&code, 17, 64);
        for plan in &plans {
            assert_eq!(plan.blocks_read(), 3, "local repair reads k/l = 3");
            assert_eq!(execute(plan, &code, &all), all[plan.failed_block]);
        }
    }

    #[test]
    fn unrecoverable_stripe_is_an_error_not_a_panic() {
        // (2,1)-RS: losing 2 blocks of a 3-block stripe leaves 1 < k
        let code = CodeSpec::Rs { k: 2, m: 1 };
        let cluster = ClusterSpec::new(8, 3);
        let p = D3Placement::new(code, cluster).unwrap();
        let sid = 0u64;
        let sp = p.stripe(sid);
        let lost = vec![0usize, 1];
        let failed_set: HashSet<Location> =
            lost.iter().map(|&b| sp.locs[b]).collect();
        assert!(stripe_repair_plans(&p, sid, &lost, &failed_set, 0).is_err());
    }

    #[test]
    fn single_loss_reroutes_target_off_failed_nodes() {
        // RDD recovery targets only exclude the stripe's nodes; when that
        // target is itself in the failure set the planner must reroute.
        let code = CodeSpec::Rs { k: 3, m: 2 };
        let cluster = ClusterSpec::new(8, 3);
        let p = RddPlacement::new(code, cluster, 5);
        let stripes = 400u64;
        // two concurrent failures: any stripe loses at most 2 of 5 blocks,
        // so 3 = k survivors always remain, and RDD's random target lands
        // on the other dead node often enough to exercise the reroute
        let failed = vec![Location::new(0, 0), Location::new(4, 1)];
        let plans = scenario_recovery_plans(&p, stripes, &failed, 5).unwrap();
        let failed_set: HashSet<Location> = failed.iter().copied().collect();
        assert!(!plans.is_empty());
        for plan in &plans {
            assert!(!failed_set.contains(&plan.writer), "writer on a dead node");
            for &(_, loc) in &plan.direct {
                assert!(!failed_set.contains(&loc), "source on a dead node");
            }
            for agg in &plan.aggregations {
                assert!(agg.inputs.iter().all(|(_, l)| !failed_set.contains(l)));
            }
        }
    }
}
