//! Failure recovery (paper §5): minimum-cross-rack repair plans for D³,
//! the RDD/HDD baseline plans, degraded reads, full-node recovery, the
//! §5.3 layout-maintenance migration, the multi-erasure planner
//! ([`multi`]) behind the scenario engine (DESIGN.md §4–§5), and the
//! pipelined chunk-parallel plan executor ([`executor`], DESIGN.md §8).

pub mod executor;
pub mod migration;
pub mod mu;
pub mod multi;
pub mod node;
pub mod plan;

pub use executor::{execute_plans, ChunkRunner, ExecStats, ExecutorConfig, Scratch};
pub use multi::{execute_plan_bytes, scenario_recovery_plans, stripe_repair_plans};
pub use node::node_recovery_plans;
pub use plan::{plan_repair, Aggregation, RepairPlan};
