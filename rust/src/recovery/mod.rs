//! Failure recovery (paper §5): minimum-cross-rack repair plans for D³,
//! the RDD/HDD baseline plans, degraded reads, full-node recovery and the
//! §5.3 layout-maintenance migration.

pub mod migration;
pub mod mu;
pub mod node;
pub mod plan;

pub use node::node_recovery_plans;
pub use plan::{plan_repair, Aggregation, RepairPlan};
