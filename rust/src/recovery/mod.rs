//! Failure recovery (paper §5): minimum-cross-rack repair plans for D³,
//! the RDD/HDD baseline plans, degraded reads, full-node recovery, the
//! §5.3 layout-maintenance migration, the multi-erasure planner
//! ([`multi`]) behind the scenario engine (DESIGN.md §4–§5), the
//! pipelined chunk-parallel plan executor ([`executor`], DESIGN.md §8),
//! and the link-balanced deterministic scheduler that orders its work
//! ([`schedule`], DESIGN.md §10).

pub mod executor;
pub mod migration;
pub mod mu;
pub mod multi;
pub mod node;
pub mod plan;
pub mod schedule;

pub use executor::{execute_plans, ChunkRunner, ExecStats, ExecutorConfig, Scratch};
pub use multi::{execute_plan_bytes, scenario_recovery_plans, stripe_repair_plans};
pub use node::node_recovery_plans;
pub use plan::{plan_repair, Aggregation, RepairPlan};
pub use schedule::{build_task_order, plan_admission_order, SchedulePolicy, TaskOrder};
