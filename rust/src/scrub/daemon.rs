//! The continuous scrub daemon (DESIGN.md §15): an adaptive-intensity
//! controller that cycles the checksum registry under a modeled clock.
//!
//! Each cycle walks every `(stripe, block)` replica in deterministic
//! order, probing stored vs registry checksums in batches. Before each
//! batch the controller samples the fabric's activity signals
//! ([`crate::cluster::links::LinkSet::fg_active`] /
//! [`crate::cluster::links::LinkSet::recovery_active`]) and picks its
//! probe rate: `busy_mb_s` while foreground or recovery traffic is
//! live, `idle_mb_s` otherwise — and escalates back toward the idle
//! ceiling whenever the remaining registry could no longer finish
//! inside the cycle deadline at the current rate. Probe bytes are
//! charged to the real link layer ([`crate::cluster::links::LinkSet::scrub_probe`]):
//! scrub shares the QoS bank with recovery, so an active split caps
//! what the daemon can take from any port foreground I/O is using.
//!
//! **Deadline guarantee.** The cycle deadline `interval_s` is met
//! whenever `total_bytes / interval_s ≤ idle_mb_s`: the escalation rule
//! keeps the chosen rate at or above `remaining_bytes / remaining_s`,
//! and that required rate is non-increasing under the rule, so a cycle
//! that starts feasible stays feasible no matter how long the busy
//! throttle held it back. When the registry is too large for the
//! configured ceiling (infeasible by arithmetic, not by interference),
//! the cycle runs at the ceiling and reports `deadline_met: false` —
//! the controller provably meets the deadline or says it missed.

use std::collections::{BTreeMap, HashSet};
use std::sync::atomic::{AtomicBool, Ordering};

use anyhow::Result;

use crate::cluster::fabric::{quarantine_and_repair, BlockFabric};
use crate::placement::Placement;
use crate::recovery::executor::ExecutorConfig;
use crate::topology::Location;
use crate::util::json::Json;

/// Knobs of the scrub controller.
#[derive(Clone, Copy, Debug)]
pub struct ScrubConfig {
    /// Full-cycle deadline (modeled seconds): every reachable replica
    /// is visited once per interval, or the cycle reports a miss.
    pub interval_s: f64,
    /// Probe-rate ceiling (MB/s) while the fabric is idle.
    pub idle_mb_s: f64,
    /// Throttled probe rate (MB/s) while foreground or recovery
    /// traffic is active.
    pub busy_mb_s: f64,
    /// Replicas probed between activity re-samples; smaller batches
    /// react faster to load coming and going, at more sampling cost.
    pub batch: usize,
}

impl Default for ScrubConfig {
    fn default() -> ScrubConfig {
        ScrubConfig { interval_s: 86_400.0, idle_mb_s: 64.0, busy_mb_s: 8.0, batch: 64 }
    }
}

/// What one scrub cycle did.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CycleReport {
    /// Replicas whose stored checksum was compared to the registry.
    pub scanned: u64,
    /// Replicas skipped: on a failed node (the failure detector's job)
    /// or without a registry entry.
    pub skipped: u64,
    /// Corrupt replicas found by this cycle's scan.
    pub corrupt_found: u64,
    /// Found blocks rebuilt from survivors and re-verified.
    pub repaired: u64,
    /// Probe batches issued.
    pub batches: u64,
    /// Batches that ran at the throttled `busy_mb_s` rate.
    pub throttled_batches: u64,
    /// Modeled cycle duration (s) under the adaptive rate schedule.
    pub modeled_s: f64,
    /// Whether the cycle finished inside `interval_s`.
    pub deadline_met: bool,
}

/// What a daemon run did across its cycles.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DaemonReport {
    /// Per-cycle reports, in order.
    pub cycles: Vec<CycleReport>,
    /// Cycles that blew their deadline.
    pub deadline_misses: u64,
}

impl DaemonReport {
    /// Replicas probed across all cycles.
    pub fn scanned(&self) -> u64 {
        self.cycles.iter().map(|c| c.scanned).sum()
    }

    /// Corrupt replicas found across all cycles.
    pub fn corrupt_found(&self) -> u64 {
        self.cycles.iter().map(|c| c.corrupt_found).sum()
    }

    /// Blocks rebuilt and re-verified across all cycles.
    pub fn repaired(&self) -> u64 {
        self.cycles.iter().map(|c| c.repaired).sum()
    }

    /// Machine-readable report (`d3ctl scrub-daemon --json`).
    pub fn to_json(&self) -> Json {
        let cycles: Vec<Json> = self
            .cycles
            .iter()
            .map(|c| {
                let mut m = BTreeMap::new();
                m.insert("scanned".into(), Json::Num(c.scanned as f64));
                m.insert("skipped".into(), Json::Num(c.skipped as f64));
                m.insert("corrupt_found".into(), Json::Num(c.corrupt_found as f64));
                m.insert("repaired".into(), Json::Num(c.repaired as f64));
                m.insert("batches".into(), Json::Num(c.batches as f64));
                m.insert(
                    "throttled_batches".into(),
                    Json::Num(c.throttled_batches as f64),
                );
                m.insert("modeled_s".into(), Json::Num(c.modeled_s));
                m.insert("deadline_met".into(), Json::Bool(c.deadline_met));
                Json::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("cycles".into(), Json::Arr(cycles));
        m.insert("deadline_misses".into(), Json::Num(self.deadline_misses as f64));
        m.insert("scanned".into(), Json::Num(self.scanned() as f64));
        m.insert("corrupt_found".into(), Json::Num(self.corrupt_found() as f64));
        m.insert("repaired".into(), Json::Num(self.repaired() as f64));
        Json::Obj(m)
    }
}

/// Run the scrub daemon for `cycles` full passes over stripes
/// `0..stripes` (blocking; spawn it on a scoped thread to run beside
/// foreground load). `stop` is polled at every batch boundary: when it
/// goes true the daemon repairs what the interrupted scan already
/// found, records the partial cycle, and returns. On a quiet fabric the
/// whole report is a pure function of the registry contents — the
/// activity signals never fire, so cycle reports are bit-identical
/// across reruns and test-thread counts.
#[allow(clippy::too_many_arguments)]
pub fn run_daemon<F: BlockFabric>(
    fabric: &F,
    policy: &dyn Placement,
    stripes: u64,
    cfg: &ScrubConfig,
    exec: ExecutorConfig,
    cycles: u64,
    seed: u64,
    stop: &AtomicBool,
) -> Result<DaemonReport> {
    let code_len = fabric.code().len();
    let bs = fabric.block_size();
    let total_blocks = stripes * code_len as u64;
    let batch = cfg.batch.max(1) as u64;
    let mut report = DaemonReport::default();
    for _ in 0..cycles {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let failed_set: HashSet<Location> =
            fabric.failed_nodes().into_iter().collect();
        let mut cr = CycleReport::default();
        // grouped per stripe so same-stripe double corruption goes
        // through the multi-erasure planner (see quarantine_and_repair)
        let mut bad: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
        let mut visited = 0u64;
        let mut interrupted = false;
        'scan: while visited < total_blocks {
            if stop.load(Ordering::Relaxed) {
                interrupted = true;
                break 'scan;
            }
            // adaptive intensity: throttle while the fabric is busy,
            // escalate toward the idle ceiling when the remaining
            // registry would otherwise miss the cycle deadline
            let busy = fabric.links().fg_active() || fabric.links().recovery_active();
            let mut rate = if busy { cfg.busy_mb_s } else { cfg.idle_mb_s };
            if busy {
                cr.throttled_batches += 1;
            }
            let remaining_s = cfg.interval_s - cr.modeled_s;
            let remaining_mb = (total_blocks - visited) as f64 * bs as f64 / 1e6;
            if remaining_s > 0.0 {
                let need = remaining_mb / remaining_s;
                if need > rate {
                    rate = need.min(cfg.idle_mb_s);
                }
            } else {
                // already past the deadline: nothing left to save, run
                // at the ceiling and report the miss
                rate = cfg.idle_mb_s;
            }
            cr.batches += 1;
            let mut probed = 0u64;
            for i in visited..(visited + batch).min(total_blocks) {
                let (sid, b) = (i / code_len as u64, (i % code_len as u64) as usize);
                let at = fabric.locate(sid, b);
                if failed_set.contains(&at) {
                    cr.skipped += 1;
                    continue;
                }
                let Some(want) = fabric.expected_checksum(sid, b) else {
                    cr.skipped += 1;
                    continue;
                };
                let Ok(got) = fabric.stored_checksum(sid, b) else {
                    cr.skipped += 1;
                    continue;
                };
                fabric.links().scrub_probe(at, bs);
                cr.scanned += 1;
                probed += 1;
                if got != want {
                    cr.corrupt_found += 1;
                    bad.entry(sid).or_default().push(b);
                }
            }
            visited = (visited + batch).min(total_blocks);
            cr.modeled_s += probed as f64 * bs as f64 / (rate.max(1e-9) * 1e6);
        }
        if !bad.is_empty() {
            let (_, repaired) = quarantine_and_repair(fabric, policy, &bad, exec, seed)?;
            cr.repaired = repaired;
        }
        cr.deadline_met = cr.modeled_s <= cfg.interval_s * (1.0 + 1e-12);
        if !cr.deadline_met {
            report.deadline_misses += 1;
        }
        report.cycles.push(cr);
        if interrupted {
            break;
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_feasible_for_paper_scale() {
        // the documented feasibility bound: a day-long interval at the
        // default ceiling covers far more than the in-process fabrics
        // ever hold, so default runs must never report a miss
        let cfg = ScrubConfig::default();
        let total_mb = 120.0 * 9.0 * (1 << 16) as f64 / 1e6; // 120 stripes of rs-6-3 @ 64 KiB
        assert!(total_mb / cfg.interval_s < cfg.idle_mb_s);
    }
}
