//! Continuous background scrubbing (DESIGN.md §15): where
//! [`crate::cluster::fabric::run_scrub`] is a one-shot explicit pass,
//! the daemon here cycles the checksum registry forever (or for a
//! requested number of cycles) on any [`crate::cluster::fabric::BlockFabric`],
//! throttling its probe intensity against live foreground and recovery
//! activity and repairing what it finds through the shared
//! quarantine-and-repair tail.

pub mod daemon;

pub use daemon::{run_daemon, CycleReport, DaemonReport, ScrubConfig};
