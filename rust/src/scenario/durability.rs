//! Monte-Carlo durability (DESIGN.md §15): MTTDL and data-loss
//! probability from N seeded trials of the shared trace loop.
//!
//! Each trial is one accelerated life of the system: Poisson node
//! failures (a configurable fraction of which take out a whole rack —
//! the switch/power-domain events where placement policy decides
//! survival), Poisson latent-corruption arrivals on uniformly random
//! blocks, and the scrub daemon's deterministic detection schedule,
//! all merged into one time-sorted [`TraceEvent`] stream and driven
//! through [`super::trace`]'s batching loop. Repair overlaps later
//! arrivals under the modeled clock, so a slow repair rate lets
//! erasures pile up — the Luby (arXiv:2002.07904) failure-rate vs
//! repair-rate race — and a stripe whose live erasures exceed the
//! code's correction radius is a data-loss event stamped with its
//! modeled time.
//!
//! The estimator treats trials as censored draws of an exponential
//! time-to-data-loss (the XORing-Elephants availability model,
//! arXiv:1301.3791): with `k` of `n` trials losing data and `T` the
//! summed observed time (first-loss time, or the full horizon for
//! loss-free trials), MTTDL ≈ `T / k`, with the exact censored-
//! exponential 95% interval `[2T/χ²₀.₉₇₅(2k+2), 2T/χ²₀.₀₂₅(2k)]` —
//! upper bound ∞ when no trial lost data. Loss probability carries a
//! Wilson 95% interval. The model backend prices each repair round at
//! the spec's modeled rate and moves no bytes, so big sweeps are cheap;
//! the physical fabrics run the *identical* event stream through their
//! real data paths and must reproduce every counter bit-for-bit.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::cluster::fabric::{recover_with_plans_cfg, BlockFabric};
use crate::codes::CodeSpec;
use crate::placement::Placement;
use crate::recovery::executor::ExecutorConfig;
use crate::topology::{ClusterSpec, Location, SystemSpec};
use crate::util::json::Json;
use crate::util::Rng;

use super::distinct_racks;
use super::trace::{drive, TraceEvent, TraceSummary};

/// One durability experiment: the accelerated failure environment and
/// how many seeded lives to run through it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DurabilitySpec {
    /// Modeled horizon of one trial (seconds).
    pub horizon_s: f64,
    /// Poisson failure-arrival rate (events per hour) — accelerated far
    /// beyond hardware AFRs so losses happen inside the horizon; MTTDL
    /// comparisons are made at the same acceleration.
    pub fail_rate_per_hour: f64,
    /// Fraction of failure events that take out a whole rack instead of
    /// one node (correlated switch/power failures).
    pub rack_fail_prob: f64,
    /// Poisson latent-corruption rate (events per hour), each flipping
    /// one uniformly random block replica.
    pub corrupt_rate_per_hour: f64,
    /// Scrub full-cycle interval (seconds); `None` disables scrubbing —
    /// latent corruption then stays latent until a failure repair of
    /// the same stripe happens to rebuild it.
    pub scrub_interval_s: Option<f64>,
    /// Modeled aggregate repair bandwidth (MB/s) advancing the shared
    /// clock — the knob that decides how long erasures stay exposed.
    pub repair_mb_s: f64,
    /// Seeded trials per matrix cell.
    pub trials: u64,
}

impl Default for DurabilitySpec {
    fn default() -> DurabilitySpec {
        DurabilitySpec {
            horizon_s: 168.0 * 3600.0,
            fail_rate_per_hour: 3.0,
            rack_fail_prob: 0.2,
            corrupt_rate_per_hour: 6.0,
            scrub_interval_s: Some(12.0 * 3600.0),
            repair_mb_s: 0.25,
            trials: 40,
        }
    }
}

impl DurabilitySpec {
    /// Machine-readable spec echo (`d3ctl durability --json`).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("horizon_s".into(), Json::Num(self.horizon_s));
        m.insert("fail_rate_per_hour".into(), Json::Num(self.fail_rate_per_hour));
        m.insert("rack_fail_prob".into(), Json::Num(self.rack_fail_prob));
        m.insert(
            "corrupt_rate_per_hour".into(),
            Json::Num(self.corrupt_rate_per_hour),
        );
        m.insert(
            "scrub_interval_s".into(),
            self.scrub_interval_s.map_or(Json::Null, Json::Num),
        );
        m.insert("repair_mb_s".into(), Json::Num(self.repair_mb_s));
        m.insert("trials".into(), Json::Num(self.trials as f64));
        Json::Obj(m)
    }
}

const FAIL_KEY: u64 = 0xfa11_4a77;
const CORRUPT_KEY: u64 = 0xc0bb_7e57;

/// Deterministic event-kind order for same-instant events: failures
/// land before the corruption they could erase, corruption before the
/// scrub visit that could detect it.
fn event_rank(e: &TraceEvent) -> (u8, u64, u64) {
    match *e {
        TraceEvent::Fail(loc) => (0, loc.rack as u64, loc.node as u64),
        TraceEvent::Corrupt { sid, block } => (1, sid, block as u64),
        TraceEvent::Scrub { sid, block } => (2, sid, block as u64),
    }
}

/// The seeded event stream of one trial: failure arrivals (node or
/// whole-rack), corruption arrivals, and — for every corruption — the
/// scrub daemon's deterministic visit that would detect it. Block `i`
/// of the flattened registry is visited at phase
/// `((i + 0.5) / total_blocks) · interval` of every scrub cycle, so the
/// detection time of a corruption is a pure function of its block and
/// arrival time: the earliest cycle whose visit lands at or after the
/// arrival. Identical streams feed the model and the physical fabrics.
pub(crate) fn trial_events(
    spec: &DurabilitySpec,
    cluster: &ClusterSpec,
    code_len: usize,
    stripes: u64,
    seed: u64,
    trial: u64,
) -> Vec<(f64, TraceEvent)> {
    let mut out: Vec<(f64, TraceEvent)> = Vec::new();
    if spec.fail_rate_per_hour > 0.0 {
        let mut rng = Rng::keyed(seed, FAIL_KEY, trial);
        let mean = 3600.0 / spec.fail_rate_per_hour;
        let mut t = 0.0;
        loop {
            t += rng.exp(mean);
            if t > spec.horizon_s {
                break;
            }
            if rng.f64() < spec.rack_fail_prob {
                let rack = rng.below(cluster.racks);
                for node in 0..cluster.nodes_per_rack {
                    out.push((t, TraceEvent::Fail(Location::new(rack, node))));
                }
            } else {
                out.push((
                    t,
                    TraceEvent::Fail(cluster.unflat(rng.below(cluster.node_count()))),
                ));
            }
        }
    }
    let total_blocks = stripes * code_len as u64;
    if spec.corrupt_rate_per_hour > 0.0 && total_blocks > 0 {
        let mut rng = Rng::keyed(seed, CORRUPT_KEY, trial);
        let mean = 3600.0 / spec.corrupt_rate_per_hour;
        let mut t = 0.0;
        loop {
            t += rng.exp(mean);
            if t > spec.horizon_s {
                break;
            }
            let i = rng.below_u64(total_blocks);
            let (sid, block) = (i / code_len as u64, (i % code_len as u64) as usize);
            out.push((t, TraceEvent::Corrupt { sid, block }));
            if let Some(interval) = spec.scrub_interval_s {
                let phase = (i as f64 + 0.5) / total_blocks as f64 * interval;
                let cycle = ((t - phase) / interval).ceil().max(0.0);
                let detect = cycle * interval + phase;
                if detect <= spec.horizon_s {
                    out.push((detect, TraceEvent::Scrub { sid, block }));
                }
            }
        }
    }
    out.sort_by(|a, b| {
        a.0.partial_cmp(&b.0)
            .unwrap()
            .then_with(|| event_rank(&a.1).cmp(&event_rank(&b.1)))
    });
    out
}

/// One trial on the pure model backend: the hooks move nothing, each
/// repair round is priced at the spec's modeled rate, and the summary
/// is a pure function of `(policy, spec, seed, trial)` — this is what
/// the big sweeps run.
pub fn run_durability_trial_model(
    policy: &dyn Placement,
    block_size: u64,
    stripes: u64,
    spec: &DurabilitySpec,
    seed: u64,
    trial: u64,
) -> Result<TraceSummary> {
    let events = trial_events(
        spec,
        &policy.cluster(),
        policy.code().len(),
        stripes,
        seed,
        trial,
    );
    drive(
        policy,
        block_size,
        stripes,
        &events,
        spec.horizon_s,
        spec.repair_mb_s,
        seed,
        |_loc| {},
        |_sid, _b| Ok(()),
        |plans, _batch| {
            Ok(plans.len() as f64 * block_size as f64 / (spec.repair_mb_s.max(1e-9) * 1e6))
        },
        |_loc| Ok(()),
    )
}

/// The same trial on a physical fabric (MiniCluster or NetCluster):
/// real node failures, real corrupted replicas, real repairs through
/// the pipelined executor, real rejoin-and-rebalance. Every counter
/// must match [`run_durability_trial_model`] for the same
/// `(seed, trial)` bit-for-bit — the spot check behind the sweeps.
pub fn run_durability_trial<F: BlockFabric>(
    fabric: &F,
    policy: &dyn Placement,
    stripes: u64,
    spec: &DurabilitySpec,
    cfg: ExecutorConfig,
    seed: u64,
    trial: u64,
) -> Result<TraceSummary> {
    let events = trial_events(
        spec,
        &policy.cluster(),
        fabric.code().len(),
        stripes,
        seed,
        trial,
    );
    drive(
        policy,
        fabric.block_size(),
        stripes,
        &events,
        spec.horizon_s,
        spec.repair_mb_s,
        seed,
        |loc| fabric.fail_node(loc),
        |sid, b| fabric.corrupt_stored(sid, b),
        |plans, batch| {
            let racks = distinct_racks(batch);
            let stats = recover_with_plans_cfg(fabric, plans.to_vec(), cfg, &racks)?;
            Ok(stats.wall.as_secs_f64())
        },
        |loc| fabric.rejoin_node(loc).map(|_| ()),
    )
}

/// MTTDL and loss-probability estimates over one cell's trials.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MttdlEstimate {
    /// Trials run.
    pub trials: u64,
    /// Trials that lost at least one stripe.
    pub losses: u64,
    /// Summed observed time (s): first-loss time per losing trial, the
    /// full horizon per censored (loss-free) trial.
    pub observed_s: f64,
    /// Censored-exponential MLE `observed_s / losses`; `None` when no
    /// trial lost data (only the lower confidence bound is informative).
    pub mttdl_s: Option<f64>,
    /// 95% lower confidence bound on MTTDL (s).
    pub mttdl_lo_s: f64,
    /// 95% upper confidence bound on MTTDL (s); ∞ when `losses == 0`.
    pub mttdl_hi_s: f64,
    /// Fraction of trials losing data inside the horizon.
    pub loss_prob: f64,
    /// Wilson 95% interval on the loss probability.
    pub loss_prob_lo: f64,
    pub loss_prob_hi: f64,
}

impl MttdlEstimate {
    /// JSON cell (`d3ctl durability --json`); hours, not seconds, and
    /// `null` for the non-finite bounds JSON cannot carry.
    pub fn to_json(&self) -> Json {
        let finite = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        let mut m = BTreeMap::new();
        m.insert("trials".into(), Json::Num(self.trials as f64));
        m.insert("losses".into(), Json::Num(self.losses as f64));
        m.insert("observed_h".into(), Json::Num(self.observed_s / 3600.0));
        m.insert(
            "mttdl_h".into(),
            self.mttdl_s.map_or(Json::Null, |s| Json::Num(s / 3600.0)),
        );
        m.insert(
            "mttdl_ci95_h".into(),
            Json::Arr(vec![
                finite(self.mttdl_lo_s / 3600.0),
                finite(self.mttdl_hi_s / 3600.0),
            ]),
        );
        m.insert("loss_prob".into(), Json::Num(self.loss_prob));
        m.insert(
            "loss_prob_ci95".into(),
            Json::Arr(vec![Json::Num(self.loss_prob_lo), Json::Num(self.loss_prob_hi)]),
        );
        Json::Obj(m)
    }
}

/// Acklam's rational approximation of the standard normal quantile
/// (|ε| < 1.2e-9 over (0, 1)) — no special-function dependency.
fn normal_quantile(p: f64) -> f64 {
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p <= 0.0 {
        return f64::NEG_INFINITY;
    }
    if p >= 1.0 {
        return f64::INFINITY;
    }
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Chi-square quantile: exact for 2 degrees of freedom (χ²₂ is
/// exponential, the `losses ≤ 1` cases where tail accuracy matters
/// most), Wilson–Hilferty otherwise (≤ a few percent at the small even
/// dof the estimator uses).
fn chi2_quantile(p: f64, df: f64) -> f64 {
    if df <= 0.0 {
        return 0.0;
    }
    if df == 2.0 {
        return -2.0 * (1.0 - p).ln();
    }
    let a = 2.0 / (9.0 * df);
    let x = 1.0 - a + normal_quantile(p) * a.sqrt();
    df * x * x * x
}

/// Wilson 95% score interval for a binomial proportion `k / n`.
fn wilson_ci(k: u64, n: u64) -> (f64, f64) {
    if n == 0 {
        return (0.0, 1.0);
    }
    let z = 1.959963984540054; // Φ⁻¹(0.975)
    let nf = n as f64;
    let p = k as f64 / nf;
    let z2 = z * z;
    let denom = 1.0 + z2 / nf;
    let center = (p + z2 / (2.0 * nf)) / denom;
    let half = z * (p * (1.0 - p) / nf + z2 / (4.0 * nf * nf)).sqrt() / denom;
    ((center - half).max(0.0), (center + half).min(1.0))
}

/// Fold one cell's trial summaries into the censored-exponential MTTDL
/// estimate (see module docs for the formula and its provenance).
pub fn estimate_mttdl(trials: &[TraceSummary]) -> MttdlEstimate {
    let n = trials.len() as u64;
    let losses = trials.iter().filter(|t| t.lost_stripes > 0).count() as u64;
    let observed_s: f64 =
        trials.iter().map(|t| t.first_loss_s.unwrap_or(t.horizon_s)).sum();
    let k = losses as f64;
    let mttdl_lo_s = 2.0 * observed_s / chi2_quantile(0.975, 2.0 * k + 2.0);
    let mttdl_hi_s = if losses > 0 {
        2.0 * observed_s / chi2_quantile(0.025, 2.0 * k)
    } else {
        f64::INFINITY
    };
    let (loss_prob_lo, loss_prob_hi) = wilson_ci(losses, n);
    MttdlEstimate {
        trials: n,
        losses,
        observed_s,
        mttdl_s: if losses > 0 { Some(observed_s / k) } else { None },
        mttdl_lo_s,
        mttdl_hi_s,
        loss_prob: if n > 0 { k / n as f64 } else { 0.0 },
        loss_prob_lo,
        loss_prob_hi,
    }
}

/// One cell of the policy × code durability matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct MatrixCell {
    /// Placement policy name (`d3`, `rdd`, …).
    pub policy: String,
    /// Code name in CLI format (`rs-6-3`, `lrc-4-2-1`).
    pub code: String,
    /// The cell's MTTDL / loss-probability estimate.
    pub est: MttdlEstimate,
    /// Stripes lost across all trials.
    pub lost_stripes: u64,
    /// Corruption arrivals across all trials.
    pub corruptions: u64,
    /// Scrub detections across all trials.
    pub scrub_detections: u64,
}

impl MatrixCell {
    /// JSON row (`d3ctl durability --json`).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("policy".into(), Json::Str(self.policy.clone()));
        m.insert("code".into(), Json::Str(self.code.clone()));
        m.insert("estimate".into(), self.est.to_json());
        m.insert("lost_stripes".into(), Json::Num(self.lost_stripes as f64));
        m.insert("corruptions".into(), Json::Num(self.corruptions as f64));
        m.insert(
            "scrub_detections".into(),
            Json::Num(self.scrub_detections as f64),
        );
        Json::Obj(m)
    }
}

/// Run the full policy × code matrix on the model backend: every cell
/// gets the same `spec.trials` seeded lives (trial `t` of every cell
/// shares the trial index, not the event stream — placements differ,
/// and failure locations are policy-independent by construction, so
/// cells are directly comparable). Returns cells in
/// `codes × policies` order.
pub fn run_matrix(
    spec: &SystemSpec,
    dspec: &DurabilitySpec,
    policies: &[String],
    codes: &[(String, CodeSpec)],
    stripes: u64,
    seed: u64,
) -> Result<Vec<MatrixCell>> {
    let mut out = Vec::new();
    for (cname, code) in codes {
        for pname in policies {
            let policy = crate::experiments::build_policy(pname, *code, spec, seed);
            let mut trials = Vec::with_capacity(dspec.trials as usize);
            for trial in 0..dspec.trials {
                trials.push(run_durability_trial_model(
                    policy.as_ref(),
                    spec.block_size,
                    stripes,
                    dspec,
                    seed,
                    trial,
                )?);
            }
            out.push(MatrixCell {
                policy: pname.clone(),
                code: cname.clone(),
                est: estimate_mttdl(&trials),
                lost_stripes: trials.iter().map(|t| t.lost_stripes).sum(),
                corruptions: trials.iter().map(|t| t.corruptions).sum(),
                scrub_detections: trials.iter().map(|t| t.scrub_detections).sum(),
            });
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::D3Placement;

    fn policy() -> D3Placement {
        D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, ClusterSpec::new(8, 3)).unwrap()
    }

    #[test]
    fn trial_events_are_deterministic_sorted_and_typed() {
        let spec = DurabilitySpec {
            horizon_s: 24.0 * 3600.0,
            fail_rate_per_hour: 2.0,
            rack_fail_prob: 0.25,
            corrupt_rate_per_hour: 4.0,
            scrub_interval_s: Some(6.0 * 3600.0),
            ..DurabilitySpec::default()
        };
        let cluster = ClusterSpec::new(8, 3);
        let a = trial_events(&spec, &cluster, 5, 20, 9, 0);
        let b = trial_events(&spec, &cluster, 5, 20, 9, 0);
        assert_eq!(a, b, "same (seed, trial) must replay exactly");
        assert!(a.windows(2).all(|w| w[0].0 <= w[1].0), "sorted by time");
        assert!(a.iter().all(|&(t, _)| t >= 0.0 && t <= spec.horizon_s));
        let kinds = |ev: &[(f64, TraceEvent)]| {
            let f = ev.iter().filter(|(_, e)| matches!(e, TraceEvent::Fail(_))).count();
            let c = ev
                .iter()
                .filter(|(_, e)| matches!(e, TraceEvent::Corrupt { .. }))
                .count();
            let s = ev
                .iter()
                .filter(|(_, e)| matches!(e, TraceEvent::Scrub { .. }))
                .count();
            (f, c, s)
        };
        let (f, c, s) = kinds(&a);
        assert!(f > 0 && c > 0, "both processes should fire over a day");
        assert!(s <= c, "at most one scrub visit per corruption");
        assert!(s > 0, "a 6h scrub interval detects most of a day's corruption");
        let other = trial_events(&spec, &cluster, 5, 20, 9, 1);
        assert_ne!(a, other, "different trial, different stream");
        // every scrub visit lands at or after its corruption's arrival
        for (t, e) in &a {
            if let TraceEvent::Scrub { sid, block } = e {
                let arr = a
                    .iter()
                    .find(|(_, e2)| {
                        matches!(e2, TraceEvent::Corrupt { sid: s2, block: b2 }
                            if s2 == sid && b2 == block)
                    })
                    .expect("scrub event without a corruption");
                assert!(*t >= arr.0, "detection before arrival");
            }
        }
    }

    #[test]
    fn model_trials_are_deterministic_and_censoring_adds_up() {
        let p = policy();
        let spec = DurabilitySpec {
            horizon_s: 48.0 * 3600.0,
            fail_rate_per_hour: 6.0,
            rack_fail_prob: 0.3,
            corrupt_rate_per_hour: 6.0,
            scrub_interval_s: Some(6.0 * 3600.0),
            repair_mb_s: 0.05,
            trials: 6,
        };
        let bs = 1 << 20;
        let mut summaries = Vec::new();
        for trial in 0..spec.trials {
            let a = run_durability_trial_model(&p, bs, 24, &spec, 11, trial).unwrap();
            let b = run_durability_trial_model(&p, bs, 24, &spec, 11, trial).unwrap();
            assert_eq!(a, b, "same (seed, trial) must replay exactly");
            if let Some(t) = a.first_loss_s {
                assert!(a.lost_stripes > 0);
                assert!((0.0..=spec.horizon_s).contains(&t));
            } else {
                assert_eq!(a.lost_stripes, 0);
            }
            assert!(a.corrupt_repaired + a.scrub_detections <= a.corruptions * 2);
            summaries.push(a);
        }
        let est = estimate_mttdl(&summaries);
        assert_eq!(est.trials, spec.trials);
        assert_eq!(
            est.losses as usize,
            summaries.iter().filter(|s| s.lost_stripes > 0).count()
        );
        assert!(est.observed_s > 0.0 && est.observed_s <= spec.horizon_s * spec.trials as f64);
    }

    #[test]
    fn estimator_brackets_the_point_and_handles_zero_losses() {
        // three losses at known times + one censored trial
        let mk = |loss: Option<f64>| TraceSummary {
            lost_stripes: u64::from(loss.is_some()),
            first_loss_s: loss,
            horizon_s: 1000.0,
            ..TraceSummary::default()
        };
        let trials =
            vec![mk(Some(100.0)), mk(Some(400.0)), mk(Some(250.0)), mk(None)];
        let est = estimate_mttdl(&trials);
        assert_eq!((est.trials, est.losses), (4, 3));
        let t = 100.0 + 400.0 + 250.0 + 1000.0;
        assert_eq!(est.observed_s, t);
        let point = est.mttdl_s.unwrap();
        assert!((point - t / 3.0).abs() < 1e-9);
        assert!(est.mttdl_lo_s < point && point < est.mttdl_hi_s);
        assert!(est.mttdl_hi_s.is_finite());
        assert!(est.loss_prob_lo <= est.loss_prob && est.loss_prob <= est.loss_prob_hi);
        // no losses: point undefined, upper bound infinite, lower bound real
        let censored: Vec<TraceSummary> = (0..5).map(|_| mk(None)).collect();
        let est0 = estimate_mttdl(&censored);
        assert_eq!(est0.losses, 0);
        assert!(est0.mttdl_s.is_none());
        assert!(est0.mttdl_hi_s.is_infinite());
        assert!(est0.mttdl_lo_s > 0.0 && est0.mttdl_lo_s.is_finite());
        assert_eq!(est0.loss_prob, 0.0);
        // JSON carries null, never inf
        let j = est0.to_json().to_string();
        assert!(!j.contains("inf"), "non-finite leaked into JSON: {j}");
    }

    #[test]
    fn quantile_helpers_match_known_values() {
        assert!((normal_quantile(0.975) - 1.959964).abs() < 1e-5);
        assert!((normal_quantile(0.5)).abs() < 1e-9);
        assert!((chi2_quantile(0.975, 2.0) - 7.377759).abs() < 1e-4, "exact at df=2");
        assert!((chi2_quantile(0.025, 2.0) - 0.050636).abs() < 1e-4);
        // Wilson–Hilferty at df=8: true χ²₀.₉₇₅(8) = 17.5345
        assert!((chi2_quantile(0.975, 8.0) - 17.5345).abs() < 0.2);
    }
}
