//! Long-horizon failure traces (DESIGN.md §14): instead of one failure
//! and one repair, node failures arrive over a modeled horizon — Poisson
//! at a configured rate, or replayed from a trace file — and repair of
//! one batch overlaps the arrival of the next.
//!
//! All backends drive the SAME batching loop against a shared *modeled*
//! clock: each round's clock advance is its repair volume over the
//! spec's modeled repair rate, never the backend's own measured time.
//! That makes event batching — and therefore every counter (failures,
//! rounds, blocks repaired, lost stripes, backlog peak) — identical on
//! the fluid simulator, the in-process cluster and the socket-backed
//! cluster, so trace runs stay cross-checkable. What each backend
//! *measures* is its own sustained repair rate: rebuilt bytes over the
//! seconds its repair path actually took (simulated seconds on the
//! fluid backend, wall seconds on the physical fabrics), reported
//! against the arrival rate the trace generated.
//!
//! The loop speaks [`TraceEvent`]s, not just node failures: latent
//! corruption arrivals (a replica silently flips; the stripe still
//! reads clean until something visits the block) and scrub visits (the
//! daemon's checksum pass reaches the block and the corruption stops
//! being latent) drive the durability engine (DESIGN.md §15). A repair
//! of a stripe always rebuilds its latent-corrupt blocks too — corrupt
//! replicas are never read as sources — and a stripe whose combined
//! failed+corrupt blocks exceed the code's correction radius is data
//! loss, recorded the round it happens.

use std::collections::{BTreeMap, BTreeSet, HashSet};

use anyhow::{bail, Context, Result};

use crate::cluster::fabric::{recover_with_plans_cfg, BlockFabric};
use crate::placement::Placement;
use crate::recovery::executor::ExecutorConfig;
use crate::recovery::multi::stripe_repair_plans;
use crate::recovery::plan::RepairPlan;
use crate::sim::recovery::{run_recovery_multi, RecoveryConfig};
use crate::topology::{ClusterSpec, Location, SystemSpec};
use crate::util::json::Json;
use crate::util::Rng;

use super::distinct_racks;

/// One event on a trace's modeled timeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TraceEvent {
    /// A node fails; its blocks are erasures until repaired + rejoined.
    Fail(Location),
    /// A replica silently corrupts (latent: reads still succeed until a
    /// scrub visit or a repair of the stripe touches it).
    Corrupt { sid: u64, block: usize },
    /// The scrub daemon's cycle visits this block; if its corruption is
    /// still latent, it is detected and the stripe repaired this round.
    Scrub { sid: u64, block: usize },
}

/// A failure-arrival process over a modeled horizon.
#[derive(Clone, Debug)]
pub struct TraceSpec {
    /// Modeled horizon in seconds; no event arrives past it.
    pub horizon_s: f64,
    /// Poisson node-failure rate (events per hour) when no explicit
    /// event list is given.
    pub rate_per_hour: f64,
    /// Modeled aggregate repair bandwidth (MB/s) that advances the
    /// shared clock between rounds — the knob that decides how many
    /// later arrivals pile into the next batch.
    pub repair_mb_s: f64,
    /// Explicit `(seconds, node)` failure events (the trace-file mode);
    /// overrides the Poisson generator.
    pub events: Option<Vec<(f64, Location)>>,
}

impl Default for TraceSpec {
    fn default() -> TraceSpec {
        TraceSpec {
            horizon_s: 86_400.0,
            rate_per_hour: 2.0,
            repair_mb_s: 64.0,
            events: None,
        }
    }
}

impl TraceSpec {
    /// The deterministic failure-event sequence: the explicit list
    /// (clamped to the horizon, sorted by time) or seeded Poisson
    /// arrivals hitting uniformly random nodes.
    pub fn arrivals(&self, cluster: &ClusterSpec, seed: u64) -> Vec<(f64, Location)> {
        if let Some(ev) = &self.events {
            let mut ev: Vec<(f64, Location)> = ev
                .iter()
                .copied()
                .filter(|&(t, _)| t >= 0.0 && t <= self.horizon_s)
                .collect();
            ev.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            return ev;
        }
        let mut rng = Rng::keyed(seed, 0x7ace_0fa1, 0);
        let mean = 3600.0 / self.rate_per_hour.max(1e-9);
        let mut t = 0.0;
        let mut out = Vec::new();
        loop {
            t += rng.exp(mean);
            if t > self.horizon_s {
                break;
            }
            out.push((t, cluster.unflat(rng.below(cluster.node_count()))));
        }
        out
    }
}

/// What a trace run did over its horizon.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TraceSummary {
    /// Node-failure events injected.
    pub failures: u64,
    /// Repair rounds executed (arrivals during a repair batch together).
    pub rounds: u64,
    /// Blocks rebuilt across all rounds.
    pub blocks_repaired: u64,
    /// Stripes that became unrecoverable (data loss) at some round.
    pub lost_stripes: u64,
    /// Latent-corruption arrivals planted on live replicas.
    pub corruptions: u64,
    /// Latent corruptions found by a scrub visit (still latent when the
    /// daemon's cycle reached the block).
    pub scrub_detections: u64,
    /// Latent-corrupt blocks rebuilt — by a scrub-triggered repair or
    /// piggybacked on a failure repair of the same stripe.
    pub corrupt_repaired: u64,
    /// Repair work generated per second of horizon (MB/s).
    pub arrival_mb_s: f64,
    /// Rebuilt bytes over the backend's measured repair seconds (MB/s).
    pub sustained_mb_s: f64,
    /// Largest repair backlog (blocks) at any round start.
    pub backlog_peak: u64,
    /// Modeled horizon (s), echoed from the spec.
    pub horizon_s: f64,
    /// Modeled time of the first data-loss event, if any occurred.
    pub first_loss_s: Option<f64>,
}

impl TraceSummary {
    /// Machine-readable counters (`d3ctl trace --json`, the durability
    /// engine's per-trial records). `sustained_mb_s` is the one
    /// backend-measured field; everything else is modeled-clock exact.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("failures".into(), Json::Num(self.failures as f64));
        m.insert("rounds".into(), Json::Num(self.rounds as f64));
        m.insert("blocks_repaired".into(), Json::Num(self.blocks_repaired as f64));
        m.insert("lost_stripes".into(), Json::Num(self.lost_stripes as f64));
        m.insert("corruptions".into(), Json::Num(self.corruptions as f64));
        m.insert("scrub_detections".into(), Json::Num(self.scrub_detections as f64));
        m.insert("corrupt_repaired".into(), Json::Num(self.corrupt_repaired as f64));
        m.insert("arrival_mb_s".into(), Json::Num(self.arrival_mb_s));
        m.insert("sustained_mb_s".into(), Json::Num(self.sustained_mb_s));
        m.insert("backlog_peak".into(), Json::Num(self.backlog_peak as f64));
        m.insert("horizon_s".into(), Json::Num(self.horizon_s));
        m.insert(
            "first_loss_s".into(),
            self.first_loss_s.map_or(Json::Null, Json::Num),
        );
        Json::Obj(m)
    }
}

/// Parse a failure-trace file: one `seconds rack node` triple per line;
/// `#` starts a comment, blank lines are skipped.
pub fn parse_trace(text: &str, cluster: &ClusterSpec) -> Result<Vec<(f64, Location)>> {
    let mut events = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(t), Some(r), Some(n)) = (it.next(), it.next(), it.next()) else {
            bail!("trace line {}: expected `seconds rack node`, got {line:?}", ln + 1);
        };
        let t: f64 = t
            .parse()
            .with_context(|| format!("trace line {}: bad time {t:?}", ln + 1))?;
        if !t.is_finite() || t < 0.0 {
            bail!("trace line {}: time must be finite and non-negative", ln + 1);
        }
        let rack: usize = r
            .parse()
            .with_context(|| format!("trace line {}: bad rack {r:?}", ln + 1))?;
        let node: usize = n
            .parse()
            .with_context(|| format!("trace line {}: bad node {n:?}", ln + 1))?;
        if rack >= cluster.racks || node >= cluster.nodes_per_rack {
            bail!(
                "trace line {}: r{rack}n{node} outside the {}×{} cluster",
                ln + 1,
                cluster.racks,
                cluster.nodes_per_rack
            );
        }
        events.push((t, Location::new(rack, node)));
    }
    events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    Ok(events)
}

/// Per-round repair plans against the canonical layout (every round
/// starts canonical: failed nodes of the previous round rejoined and
/// their blocks rebalanced home). A stripe is planned when a failed
/// node holds one of its blocks or a scrub visit detected latent
/// corruption on it; either way the plan also rebuilds every
/// latent-corrupt block of the stripe — corrupt replicas must never be
/// read as sources, and a repaired stripe comes back clean. Stripes
/// whose combined failed+corrupt blocks exceed the code's correction
/// radius are recorded in `lost` and never planned again; returns the
/// plans, the number of newly lost stripes, and the planned stripe ids.
#[allow(clippy::too_many_arguments)]
fn round_plans(
    policy: &dyn Placement,
    layout: &[Vec<Location>],
    failed: &[Location],
    scrub_sids: &BTreeSet<u64>,
    corrupt: &BTreeMap<u64, BTreeSet<usize>>,
    lost: &mut HashSet<u64>,
    seed: u64,
) -> (Vec<RepairPlan>, u64, Vec<u64>) {
    let failed_set: HashSet<Location> = failed.iter().copied().collect();
    let mut plans = Vec::new();
    let mut newly_lost = 0u64;
    let mut planned = Vec::new();
    for (sid, locs) in layout.iter().enumerate() {
        let sid = sid as u64;
        if lost.contains(&sid) {
            continue;
        }
        let mut lost_blocks: Vec<usize> = (0..locs.len())
            .filter(|&b| failed_set.contains(&locs[b]))
            .collect();
        if lost_blocks.is_empty() && !scrub_sids.contains(&sid) {
            continue;
        }
        if let Some(bad) = corrupt.get(&sid) {
            for &b in bad {
                if !lost_blocks.contains(&b) {
                    lost_blocks.push(b);
                }
            }
            lost_blocks.sort_unstable();
        }
        match stripe_repair_plans(policy, sid, &lost_blocks, &failed_set, seed) {
            Ok(ps) => {
                plans.extend(ps);
                planned.push(sid);
            }
            Err(_) => {
                lost.insert(sid);
                newly_lost += 1;
            }
        }
    }
    (plans, newly_lost, planned)
}

/// The ONE batching loop every backend runs: pull due events, fail the
/// batch and plant its corruption, plan (tolerating unrecoverable
/// stripes), execute via the backend's `execute` hook (which returns
/// its measured repair seconds), rejoin the batch, and advance the
/// shared modeled clock. Counters are a pure function of (layout,
/// events, seed) — the hooks move real bytes or nothing at all, and
/// every backend batches identically because the clock is modeled.
#[allow(clippy::too_many_arguments)]
pub(crate) fn drive<K, P, E, J>(
    policy: &dyn Placement,
    block_size: u64,
    stripes: u64,
    events: &[(f64, TraceEvent)],
    horizon_s: f64,
    repair_mb_s: f64,
    seed: u64,
    mut fail: K,
    mut plant: P,
    mut execute: E,
    mut rejoin: J,
) -> Result<TraceSummary>
where
    K: FnMut(Location),
    P: FnMut(u64, usize) -> Result<()>,
    E: FnMut(&[RepairPlan], &[Location]) -> Result<f64>,
    J: FnMut(Location) -> Result<()>,
{
    // the canonical layout, resolved once: round planning is a pure
    // scan over it, and long trials visit every stripe every round
    let layout: Vec<Vec<Location>> =
        (0..stripes).map(|sid| policy.stripe(sid).locs).collect();
    let mut summary = TraceSummary {
        failures: events
            .iter()
            .filter(|(_, e)| matches!(e, TraceEvent::Fail(_)))
            .count() as u64,
        horizon_s,
        ..TraceSummary::default()
    };
    let mut lost: HashSet<u64> = HashSet::new();
    // latent corruption: stripe → set of silently-flipped block indices
    let mut corrupt: BTreeMap<u64, BTreeSet<usize>> = BTreeMap::new();
    let mut clock = 0.0f64;
    let mut repair_s = 0.0f64;
    let mut i = 0usize;
    while i < events.len() {
        // idle until the next arrival, then batch everything already due
        clock = clock.max(events[i].0);
        let mut fails: Vec<Location> = Vec::new();
        let mut plants: Vec<(u64, usize)> = Vec::new();
        let mut detects: Vec<(u64, usize)> = Vec::new();
        while i < events.len() && events[i].0 <= clock {
            match events[i].1 {
                TraceEvent::Fail(loc) => {
                    if !fails.contains(&loc) {
                        fails.push(loc);
                    }
                }
                TraceEvent::Corrupt { sid, block } => plants.push((sid, block)),
                TraceEvent::Scrub { sid, block } => detects.push((sid, block)),
            }
            i += 1;
        }
        let failed_set: HashSet<Location> = fails.iter().copied().collect();
        for &loc in &fails {
            fail(loc);
        }
        // corruption arrivals: skip stripes already lost and replicas
        // erased by this same batch's failures (nothing left to flip);
        // the set insert dedups so a double arrival can't flip a
        // physical replica back to clean
        let mut touched: Vec<u64> = Vec::new();
        for (sid, b) in plants {
            if lost.contains(&sid) || b >= layout[sid as usize].len() {
                continue;
            }
            if failed_set.contains(&layout[sid as usize][b]) {
                continue;
            }
            if corrupt.entry(sid).or_default().insert(b) {
                summary.corruptions += 1;
                plant(sid, b)?;
                if !touched.contains(&sid) {
                    touched.push(sid);
                }
            }
        }
        // scrub visits: only still-latent corruption is a detection
        let mut scrub_sids: BTreeSet<u64> = BTreeSet::new();
        for (sid, b) in detects {
            if lost.contains(&sid) {
                continue;
            }
            if corrupt.get(&sid).is_some_and(|s| s.contains(&b)) {
                summary.scrub_detections += 1;
                scrub_sids.insert(sid);
            }
        }
        let (plans, newly_lost, planned) =
            round_plans(policy, &layout, &fails, &scrub_sids, &corrupt, &mut lost, seed);
        summary.lost_stripes += newly_lost;
        // recoverability probe for stripes that only accumulated latent
        // corruption this round: nothing repairs them yet, but if the
        // corruption alone already exceeds the code's correction radius
        // the data is gone — record the loss at arrival time
        for &sid in &touched {
            if lost.contains(&sid) || planned.contains(&sid) {
                continue;
            }
            let bad: Vec<usize> = corrupt[&sid].iter().copied().collect();
            if stripe_repair_plans(policy, sid, &bad, &failed_set, seed).is_err() {
                lost.insert(sid);
                summary.lost_stripes += 1;
            }
        }
        if summary.first_loss_s.is_none() && summary.lost_stripes > 0 {
            summary.first_loss_s = Some(clock);
        }
        summary.backlog_peak = summary.backlog_peak.max(plans.len() as u64);
        // corruption-only batches don't open a repair round; failure
        // batches always do (even when no stripe was hit), exactly as
        // the failure-only loop counted them
        if !fails.is_empty() || !plans.is_empty() {
            summary.rounds += 1;
        }
        if !plans.is_empty() {
            repair_s += execute(&plans, &fails)?;
            summary.blocks_repaired += plans.len() as u64;
        }
        // repaired stripes come back fully clean: their latent set dies
        for &sid in &planned {
            if let Some(bad) = corrupt.remove(&sid) {
                summary.corrupt_repaired += bad.len() as u64;
            }
        }
        for &loc in &fails {
            rejoin(loc)?;
        }
        // modeled makespan, NOT measured time: identical on every
        // backend, so later arrivals batch identically everywhere
        clock += plans.len() as f64 * block_size as f64 / (repair_mb_s.max(1e-9) * 1e6);
    }
    let total_bytes = summary.blocks_repaired as f64 * block_size as f64;
    summary.arrival_mb_s = total_bytes / horizon_s.max(1e-9) / 1e6;
    summary.sustained_mb_s =
        if repair_s > 0.0 { total_bytes / repair_s / 1e6 } else { 0.0 };
    Ok(summary)
}

/// Run a failure trace against a physical fabric (MiniCluster or
/// NetCluster): real failures, real repairs through the pipelined
/// executor, real rejoin-and-rebalance between rounds. Sustained rate is
/// measured from the executor's wall clock.
pub fn run_trace<F: BlockFabric>(
    fabric: &F,
    policy: &dyn Placement,
    stripes: u64,
    spec: &TraceSpec,
    cfg: ExecutorConfig,
    seed: u64,
) -> Result<TraceSummary> {
    let events = fail_events(spec, &policy.cluster(), seed);
    drive(
        policy,
        fabric.block_size(),
        stripes,
        &events,
        spec.horizon_s,
        spec.repair_mb_s,
        seed,
        |loc| fabric.fail_node(loc),
        |sid, b| fabric.corrupt_stored(sid, b),
        |plans, batch| {
            let racks = distinct_racks(batch);
            let stats = recover_with_plans_cfg(fabric, plans.to_vec(), cfg, &racks)?;
            Ok(stats.wall.as_secs_f64())
        },
        |loc| fabric.rejoin_node(loc).map(|_| ()),
    )
}

/// Run a failure trace on the fluid simulator: the identical batching
/// loop, with each round priced by [`run_recovery_multi`]'s simulated
/// makespan. The simulator carries no persistent stores, so fail/rejoin
/// are pure bookkeeping (the canonical layout IS its state).
pub fn run_trace_sim(
    spec: &SystemSpec,
    policy: &dyn Placement,
    stripes: u64,
    tspec: &TraceSpec,
    cfg: RecoveryConfig,
    seed: u64,
) -> Result<TraceSummary> {
    let cfg = RecoveryConfig { period: cfg.period.or_else(|| policy.period()), ..cfg };
    let events = fail_events(tspec, &policy.cluster(), seed);
    drive(
        policy,
        spec.block_size,
        stripes,
        &events,
        tspec.horizon_s,
        tspec.repair_mb_s,
        seed,
        |_loc| {},
        |_sid, _b| Ok(()),
        |plans, batch| {
            let racks = distinct_racks(batch);
            let (out, _) = run_recovery_multi(spec, plans, &racks, cfg, Vec::new());
            Ok(out.makespan)
        },
        |_loc| Ok(()),
    )
}

/// A [`TraceSpec`]'s failure arrivals as a [`TraceEvent`] stream (the
/// failure-only trace mode; the durability engine merges corruption and
/// scrub events in on top).
fn fail_events(
    spec: &TraceSpec,
    cluster: &ClusterSpec,
    seed: u64,
) -> Vec<(f64, TraceEvent)> {
    spec.arrivals(cluster, seed)
        .into_iter()
        .map(|(t, loc)| (t, TraceEvent::Fail(loc)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::CodeSpec;
    use crate::placement::D3Placement;

    fn policy() -> D3Placement {
        D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, ClusterSpec::new(8, 3)).unwrap()
    }

    #[test]
    fn poisson_arrivals_are_deterministic_and_inside_horizon() {
        let cluster = ClusterSpec::new(8, 3);
        let spec = TraceSpec { horizon_s: 7200.0, rate_per_hour: 6.0, ..TraceSpec::default() };
        let a = spec.arrivals(&cluster, 42);
        let b = spec.arrivals(&cluster, 42);
        assert_eq!(a, b, "same seed, same trace");
        assert!(!a.is_empty(), "6/h over 2 h should fire");
        assert!(a.windows(2).all(|w| w[0].0 <= w[1].0), "sorted by time");
        assert!(a.iter().all(|&(t, _)| t >= 0.0 && t <= 7200.0));
        let c = spec.arrivals(&cluster, 43);
        assert_ne!(a, c, "different seed, different trace");
    }

    #[test]
    fn poisson_rate_roughly_matches() {
        let cluster = ClusterSpec::new(8, 3);
        let spec = TraceSpec {
            horizon_s: 3600.0 * 1000.0,
            rate_per_hour: 4.0,
            ..TraceSpec::default()
        };
        let n = spec.arrivals(&cluster, 7).len() as f64;
        let want = 4000.0;
        assert!(
            (n - want).abs() < want * 0.1,
            "expected ≈{want} events, got {n}"
        );
    }

    #[test]
    fn parse_trace_accepts_comments_and_rejects_garbage() {
        let cluster = ClusterSpec::new(8, 3);
        let ev = parse_trace(
            "# a comment\n10.5 0 1\n\n3 7 2  # inline comment\n",
            &cluster,
        )
        .unwrap();
        assert_eq!(
            ev,
            vec![(3.0, Location::new(7, 2)), (10.5, Location::new(0, 1))],
            "sorted by time"
        );
        assert!(parse_trace("nonsense", &cluster).is_err());
        assert!(parse_trace("1.0 0", &cluster).is_err(), "missing node");
        assert!(parse_trace("-1 0 0", &cluster).is_err(), "negative time");
        assert!(parse_trace("1 99 0", &cluster).is_err(), "rack out of range");
    }

    #[test]
    fn explicit_events_clamp_to_horizon() {
        let cluster = ClusterSpec::new(8, 3);
        let spec = TraceSpec {
            horizon_s: 100.0,
            events: Some(vec![
                (150.0, Location::new(0, 0)),
                (50.0, Location::new(1, 1)),
                (10.0, Location::new(2, 2)),
            ]),
            ..TraceSpec::default()
        };
        let ev = spec.arrivals(&cluster, 0);
        assert_eq!(ev.len(), 2, "event past the horizon dropped");
        assert_eq!(ev[0].0, 10.0);
    }

    #[test]
    fn sim_trace_counters_are_seed_deterministic() {
        let p = policy();
        let mut spec = SystemSpec::paper_default();
        spec.block_size = 1 << 20;
        let tspec = TraceSpec {
            horizon_s: 6.0 * 3600.0,
            rate_per_hour: 1.0,
            repair_mb_s: 16.0,
            ..TraceSpec::default()
        };
        let a = run_trace_sim(&spec, &p, 40, &tspec, RecoveryConfig::default(), 5).unwrap();
        let b = run_trace_sim(&spec, &p, 40, &tspec, RecoveryConfig::default(), 5).unwrap();
        assert_eq!(a, b, "same seed must replay exactly");
        assert_eq!(a.failures as usize, tspec.arrivals(&p.cluster(), 5).len());
        assert!(a.rounds >= 1 && a.rounds <= a.failures);
        assert!(a.blocks_repaired > 0, "a failing node should lose blocks");
        assert_eq!(a.lost_stripes, 0, "single failures never lose stripes");
        assert!(a.sustained_mb_s > 0.0);
        assert!(a.arrival_mb_s > 0.0);
    }
}
