//! The failure-scenario engine (DESIGN.md §5): first-class, backend-
//! agnostic failure scenarios.
//!
//! A [`FailureScenario`] describes *what goes wrong* — which nodes die,
//! what load competes with recovery — independently of *how the outcome is
//! measured*. A [`RecoveryBackend`] executes a scenario and reports a
//! [`ScenarioOutcome`]; the two implementations are
//!
//! * [`crate::sim::recovery::SimBackend`] — the fluid discrete-event
//!   simulator (simulated seconds, analytic port loads), and
//! * [`crate::cluster::ClusterBackend`] — the in-process MiniCluster
//!   (real bytes through throttled links, wall-clock seconds),
//!
//! so every scenario is cross-checkable: the same failure set and the same
//! repair plans drive both, and backend-independent quantities (blocks
//! rebuilt, planned cross-rack block transfers, relative cross-rack bytes
//! between policies) must agree.
//!
//! The paper evaluates single-node failures only; the scenario kinds add
//! the correlated failures that dominate production repair traffic
//! (multi-node, whole-rack — see Rashmi et al., arXiv:1309.0186) plus the
//! front-end-load and degraded-read-burst mixes of §6.2.3–§6.2.4.

pub mod durability;
pub mod trace;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::client::{FgSpec, QosConfig, Request, RequestClass};
use crate::placement::{Placement, PlacementTable};
use crate::recovery::multi::scenario_recovery_plans;
use crate::recovery::plan::{plan_degraded_read, RepairPlan};
use crate::topology::{Location, SystemSpec};
use crate::util::json::Json;
use crate::util::Rng;

/// What goes wrong.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ScenarioKind {
    /// One node fails (the paper's §6 setting).
    SingleNode,
    /// `failures` nodes fail concurrently (correlated failure).
    MultiNode { failures: usize },
    /// Every node of one rack fails (switch/power-domain failure).
    RackFailure { rack: u32 },
    /// One node fails while a front-end workload runs (paper Exp 11).
    FrontendMix { workload: String },
    /// One node fails and `reads` clients immediately degraded-read lost
    /// blocks (paper Exp 3, but as a concurrent burst).
    DegradedBurst { reads: usize },
}

/// A failure scenario: the kind, the stored-stripe population it hits,
/// the seed that makes every derived choice (failed nodes, foreground
/// requests) deterministic and identical across backends, plus the
/// mixed-load parameters — the QoS split and an optional explicit
/// foreground-traffic spec (DESIGN.md §11). Any kind becomes a mixed-load
/// scenario via [`FailureScenario::with_fg`]; `FrontendMix` and
/// `DegradedBurst` derive their foreground spec from the kind itself.
#[derive(Clone, Debug)]
pub struct FailureScenario {
    pub kind: ScenarioKind,
    pub stripes: u64,
    pub seed: u64,
    /// Recovery/foreground bandwidth split applied while foreground load
    /// is active (default: no split).
    pub qos: QosConfig,
    /// Explicit foreground traffic; `None` derives it from the kind
    /// (`FrontendMix`/`DegradedBurst`) or runs no foreground load.
    pub fg: Option<FgSpec>,
}

impl FailureScenario {
    fn new(kind: ScenarioKind, stripes: u64, seed: u64) -> FailureScenario {
        FailureScenario { kind, stripes, seed, qos: QosConfig::default(), fg: None }
    }

    pub fn single_node(stripes: u64, seed: u64) -> FailureScenario {
        FailureScenario::new(ScenarioKind::SingleNode, stripes, seed)
    }

    pub fn multi_node(failures: usize, stripes: u64, seed: u64) -> FailureScenario {
        FailureScenario::new(ScenarioKind::MultiNode { failures }, stripes, seed)
    }

    pub fn rack_failure(rack: u32, stripes: u64, seed: u64) -> FailureScenario {
        FailureScenario::new(ScenarioKind::RackFailure { rack }, stripes, seed)
    }

    /// One node fails while a front-end workload runs. Defaults to
    /// `recovery_share = 0.25` — the HDFS posture of throttling
    /// reconstruction under foreground load
    /// (`dfs.namenode.replication.max-streams`; the fluid backend's 8
    /// default streams × 0.25 = the 2-stream throttle this kind always
    /// ran with). Override with [`FailureScenario::with_qos`].
    pub fn frontend_mix(workload: &str, stripes: u64, seed: u64) -> FailureScenario {
        let mut s = FailureScenario::new(
            ScenarioKind::FrontendMix { workload: workload.to_string() },
            stripes,
            seed,
        );
        s.qos = QosConfig { recovery_share: 0.25, fg_weight: 1.0 };
        s
    }

    pub fn degraded_burst(reads: usize, stripes: u64, seed: u64) -> FailureScenario {
        FailureScenario::new(ScenarioKind::DegradedBurst { reads }, stripes, seed)
    }

    /// Set the recovery/foreground QoS split.
    pub fn with_qos(mut self, qos: QosConfig) -> FailureScenario {
        self.qos = qos;
        self
    }

    /// Attach explicit foreground traffic, turning any failure kind into
    /// a mixed-load scenario.
    pub fn with_fg(mut self, fg: FgSpec) -> FailureScenario {
        self.fg = Some(fg);
        self
    }

    /// The scenario's foreground-traffic spec: the explicit override if
    /// set, else the kind's derived spec (`FrontendMix` → the Table-2
    /// workload as a request mix, `DegradedBurst` → an all-degraded
    /// burst), else `None`.
    pub fn fg_spec(&self) -> Result<Option<FgSpec>> {
        if let Some(fg) = &self.fg {
            return Ok(Some(fg.clone()));
        }
        match &self.kind {
            ScenarioKind::FrontendMix { workload } => {
                Ok(Some(FgSpec::from_workload_name(workload)?))
            }
            ScenarioKind::DegradedBurst { reads } => Ok(Some(FgSpec::burst(*reads))),
            _ => Ok(None),
        }
    }

    /// The deterministic foreground request sequence both backends serve
    /// (DESIGN.md §11). `None` when the scenario carries no foreground
    /// load.
    pub fn fg_requests(
        &self,
        policy: &Arc<dyn Placement>,
    ) -> Result<Option<(FgSpec, Vec<Request>)>> {
        if self.fg_spec()?.is_none() {
            return Ok(None);
        }
        let table = PlacementTable::build(policy.clone(), self.stripes);
        self.fg_requests_with(&table)
    }

    /// [`FailureScenario::fg_requests`] against a table the caller
    /// already built — backends that need the table anyway (plan
    /// derivation, fluid job lowering) share one build per run.
    pub fn fg_requests_with(
        &self,
        table: &PlacementTable,
    ) -> Result<Option<(FgSpec, Vec<Request>)>> {
        let Some(spec) = self.fg_spec()? else {
            return Ok(None);
        };
        let failed = self.failed_nodes(table);
        let reqs = spec.generate_with(table, self.stripes, &failed, self.seed)?;
        Ok(Some((spec, reqs)))
    }

    /// Short label, e.g. `single-node`, `multi-node-2`, `rack-failure-0`.
    pub fn name(&self) -> String {
        match &self.kind {
            ScenarioKind::SingleNode => "single-node".into(),
            ScenarioKind::MultiNode { failures } => format!("multi-node-{failures}"),
            ScenarioKind::RackFailure { rack } => format!("rack-failure-{rack}"),
            ScenarioKind::FrontendMix { workload } => format!("frontend-mix-{workload}"),
            ScenarioKind::DegradedBurst { reads } => format!("degraded-burst-{reads}"),
        }
    }

    /// The deterministic failure set under `policy`'s topology. Single-node
    /// kinds pick a seed-keyed node that actually stores blocks (so the
    /// scenario is never vacuous); multi-node samples distinct nodes;
    /// rack failure takes the whole rack.
    pub fn failed_nodes(&self, policy: &dyn Placement) -> Vec<Location> {
        let cluster = policy.cluster();
        let count = cluster.node_count();
        match &self.kind {
            ScenarioKind::SingleNode
            | ScenarioKind::FrontendMix { .. }
            | ScenarioKind::DegradedBurst { .. } => {
                let mut rng = Rng::keyed(self.seed, 0x0fa1_1ed, 0);
                let start = rng.below(count);
                // one placement period proves coverage for periodic
                // policies (stripe(sid) == stripe(sid % p)); aperiodic
                // policies must probe the whole stored population — a
                // fixed 200-stripe window could miss a node whose blocks
                // all lie beyond it and declare the scenario vacuous.
                let probe = match policy.period() {
                    Some(p) => self.stripes.min(p),
                    None => self.stripes,
                };
                let mut holds = vec![false; count];
                let len = policy.code().len();
                let mut missing = count;
                'probe: for sid in 0..probe {
                    for b in 0..len {
                        let slot = cluster.flat(policy.block_at(sid, b));
                        if !holds[slot] {
                            holds[slot] = true;
                            missing -= 1;
                            if missing == 0 {
                                break 'probe;
                            }
                        }
                    }
                }
                for off in 0..count {
                    let idx = (start + off) % count;
                    if holds[idx] {
                        return vec![cluster.unflat(idx)];
                    }
                }
                vec![cluster.unflat(start)]
            }
            ScenarioKind::MultiNode { failures } => {
                let mut rng = Rng::keyed(self.seed, 0x0fa1_1ed, 1);
                let want = (*failures).clamp(1, count.saturating_sub(1));
                rng.sample_indices(count, want)
                    .into_iter()
                    .map(|i| cluster.unflat(i))
                    .collect()
            }
            ScenarioKind::RackFailure { rack } => {
                let rack = (*rack as usize).min(cluster.racks - 1);
                (0..cluster.nodes_per_rack)
                    .map(|j| Location::new(rack, j))
                    .collect()
            }
        }
    }

    /// Repair plans for this scenario's failure set, built through a
    /// table-backed placement lookup (DESIGN.md §7). Returns
    /// `(failed nodes, plans)`; both backends call this, so they always
    /// execute the *same* plans.
    pub fn recovery_plans(
        &self,
        policy: &Arc<dyn Placement>,
    ) -> Result<(Vec<Location>, Vec<RepairPlan>)> {
        let failed = self.failed_nodes(policy.as_ref());
        let table = PlacementTable::build(policy.clone(), self.stripes);
        let plans = scenario_recovery_plans(&table, self.stripes, &failed, self.seed)?;
        Ok((failed, plans))
    }

    /// For [`ScenarioKind::DegradedBurst`]: the failed node and the
    /// seed-keyed `(stripe, block, client)` read samples — now just a
    /// projection of the client engine's generated request sequence, so
    /// there is exactly one derivation of burst traffic (DESIGN.md §11).
    pub fn burst_samples(
        &self,
        policy: &Arc<dyn Placement>,
    ) -> Result<(Location, Vec<(u64, usize, Location)>)> {
        if !matches!(self.kind, ScenarioKind::DegradedBurst { .. }) {
            bail!("burst_samples on a non-burst scenario");
        }
        let failed = self.failed_nodes(policy.as_ref())[0];
        let (_, reqs) = self
            .fg_requests(policy)?
            .expect("degraded burst always carries foreground traffic");
        let samples = reqs
            .iter()
            .filter_map(|r| match r.class {
                RequestClass::DegradedRead { stripe, block } => {
                    Some((stripe, block, r.client))
                }
                _ => None,
            })
            .collect();
        Ok((failed, samples))
    }
}

/// What a backend measured for one scenario run.
#[derive(Clone, Debug)]
pub struct ScenarioOutcome {
    /// Backend that produced this outcome (`sim`, `cluster`, or `net`).
    pub backend: &'static str,
    /// Scenario label ([`FailureScenario::name`]).
    pub scenario: String,
    /// Placement policy name.
    pub policy: String,
    /// Blocks rebuilt (node/rack kinds) or degraded reads served (burst).
    pub blocks: usize,
    /// Bytes rebuilt/served at the backend's block size.
    pub bytes: u64,
    /// Recovery time: simulated seconds (sim) or wall-clock (cluster).
    pub seconds: f64,
    /// bytes / seconds, MB/s.
    pub throughput_mb_s: f64,
    /// Load-imbalance λ over surviving racks' cross-rack port loads.
    pub lambda: f64,
    /// Per-rack cross-rack bytes (up, down) during the scenario.
    pub rack_cross_bytes: Vec<(u64, u64)>,
    /// Whole-block cross-rack transfers the plans prescribe —
    /// backend-independent (the paper's "cross-rack accessed blocks").
    pub planned_cross_rack_blocks: usize,
    /// Mean degraded-read latency (burst kind only).
    pub degraded_read_mean_s: Option<f64>,
    /// Front-end workload completion time (frontend-mix kind only).
    pub frontend_seconds: Option<f64>,
    /// Per-worker busy fraction of the recovery executor (cluster backend
    /// recovery kinds only; the fluid backend has no discrete workers).
    pub worker_utilization: Option<Vec<f64>>,
    /// Scratch-buffer-pool hit/miss totals of the recovery executor's
    /// worker pools (cluster backend recovery kinds only) — near-1.0 hit
    /// rates mean the data path ran allocation-free (DESIGN.md §9).
    pub scratch_pool: Option<crate::metrics::PoolStats>,
    /// Per-rack-link (busy, stall) seconds during the scenario
    /// (DESIGN.md §10). The cluster backend measures both from its link
    /// meters; the fluid backend derives busy from port loads at the
    /// configured rate and reports zero stall (max-min fair sharing has
    /// no queueing in front of the ports).
    pub link_busy_stall: Option<Vec<(f64, f64)>>,
    /// Foreground-request latency summary (mixed-load kinds; DESIGN.md
    /// §11): count, mean, p50/p95/p99 and max over the per-request
    /// latencies of the shared client engine.
    pub fg_latency: Option<crate::metrics::Summary>,
    /// Recovery time under foreground load ÷ the same recovery alone —
    /// the interference factor the QoS split trades against foreground
    /// tail latency (mixed-load kinds that execute recovery).
    pub recovery_slowdown: Option<f64>,
    /// Chaos-layer fault counters (DESIGN.md §14) when injection was
    /// armed on the fabric; `None` on the fluid backend and unarmed runs.
    pub faults: Option<crate::metrics::FaultReport>,
    /// Long-horizon failure-trace summary (`d3ctl chaos --trace`);
    /// `None` for one-shot scenarios.
    pub trace: Option<trace::TraceSummary>,
}

impl ScenarioOutcome {
    /// Total cross-rack bytes (sum of every rack's upstream port).
    pub fn total_cross_rack_bytes(&self) -> u64 {
        self.rack_cross_bytes.iter().map(|&(up, _)| up).sum()
    }

    /// Human-readable report (the `d3ctl scenario` output).
    pub fn print(&self) {
        println!(
            "[{}] {} · {}: {} blocks ({:.1} MB) in {:.2} s → {:.1} MB/s, λ={:.3}",
            self.backend,
            self.scenario,
            self.policy,
            self.blocks,
            self.bytes as f64 / 1e6,
            self.seconds,
            self.throughput_mb_s,
            self.lambda
        );
        println!(
            "  planned cross-rack block transfers: {} · total cross-rack bytes: {:.1} MB",
            self.planned_cross_rack_blocks,
            self.total_cross_rack_bytes() as f64 / 1e6
        );
        let per_rack: Vec<String> = self
            .rack_cross_bytes
            .iter()
            .enumerate()
            .map(|(r, &(up, down))| {
                format!("r{r} {:.1}/{:.1}", up as f64 / 1e6, down as f64 / 1e6)
            })
            .collect();
        println!("  per-rack cross bytes up/down (MB): {}", per_rack.join("  "));
        if let Some(d) = self.degraded_read_mean_s {
            println!("  mean degraded-read latency: {d:.2} s");
        }
        if let Some(f) = self.frontend_seconds {
            println!("  front-end workload completion: {f:.1} s");
        }
        if let Some(u) = &self.worker_utilization {
            let cells: Vec<String> =
                u.iter().map(|x| format!("{:.0}%", x * 100.0)).collect();
            println!("  per-worker utilization: {}", cells.join(" "));
        }
        if let Some(p) = &self.scratch_pool {
            println!(
                "  scratch pool: {} hits / {} misses ({:.0}% reuse)",
                p.hits,
                p.misses,
                p.hit_rate() * 100.0
            );
        }
        if let Some(ls) = &self.link_busy_stall {
            let cells: Vec<String> = ls
                .iter()
                .enumerate()
                .map(|(r, &(b, s))| format!("r{r} {b:.2}/{s:.2}"))
                .collect();
            println!("  per-rack-link busy/stall (s): {}", cells.join("  "));
        }
        if let Some(l) = &self.fg_latency {
            println!(
                "  fg latency over {} requests: mean {:.3} s · p50/p95/p99 \
                 {:.3}/{:.3}/{:.3} s · max {:.3} s",
                l.count, l.mean, l.p50, l.p95, l.p99, l.max
            );
        }
        if let Some(x) = self.recovery_slowdown {
            println!("  recovery slowdown under foreground load: {x:.2}x");
        }
        if let Some(f) = &self.faults {
            println!(
                "  faults injected: {} (drop {} · delay {} · corrupt {} · truncate {}) — \
                 retries {} · evictions {} · crashes {} · failovers {} · replans {} · \
                 quarantined {} · scrub-repaired {}",
                f.total_injected(),
                f.drops,
                f.delays,
                f.corrupts,
                f.truncates,
                f.retries,
                f.evictions,
                f.crashes,
                f.failovers,
                f.replans,
                f.quarantined,
                f.scrub_repaired
            );
        }
        if let Some(t) = &self.trace {
            println!(
                "  trace: {} failures over {:.0} s in {} repair rounds → arrival \
                 {:.2} MB/s vs sustained repair {:.2} MB/s · backlog peak {} blocks \
                 · lost stripes {}",
                t.failures,
                t.horizon_s,
                t.rounds,
                t.arrival_mb_s,
                t.sustained_mb_s,
                t.backlog_peak,
                t.lost_stripes
            );
        }
    }

    /// The full outcome as a JSON document (`d3ctl scenario --json`), so
    /// sweeps are scriptable without parsing the human-readable report.
    pub fn to_json(&self) -> Json {
        use std::collections::BTreeMap;
        let pair_arr = |v: &[(f64, f64)]| {
            Json::Arr(
                v.iter()
                    .map(|&(a, b)| Json::Arr(vec![Json::Num(a), Json::Num(b)]))
                    .collect(),
            )
        };
        let mut m = BTreeMap::new();
        m.insert("backend".into(), Json::Str(self.backend.into()));
        m.insert("scenario".into(), Json::Str(self.scenario.clone()));
        m.insert("policy".into(), Json::Str(self.policy.clone()));
        m.insert("blocks".into(), Json::Num(self.blocks as f64));
        m.insert("bytes".into(), Json::Num(self.bytes as f64));
        m.insert("seconds".into(), Json::Num(self.seconds));
        m.insert("throughput_mb_s".into(), Json::Num(self.throughput_mb_s));
        m.insert("lambda".into(), Json::Num(self.lambda));
        m.insert(
            "rack_cross_bytes".into(),
            Json::Arr(
                self.rack_cross_bytes
                    .iter()
                    .map(|&(u, d)| {
                        Json::Arr(vec![Json::Num(u as f64), Json::Num(d as f64)])
                    })
                    .collect(),
            ),
        );
        m.insert(
            "planned_cross_rack_blocks".into(),
            Json::Num(self.planned_cross_rack_blocks as f64),
        );
        if let Some(d) = self.degraded_read_mean_s {
            m.insert("degraded_read_mean_s".into(), Json::Num(d));
        }
        if let Some(f) = self.frontend_seconds {
            m.insert("frontend_seconds".into(), Json::Num(f));
        }
        if let Some(u) = &self.worker_utilization {
            m.insert(
                "worker_utilization".into(),
                Json::Arr(u.iter().map(|&x| Json::Num(x)).collect()),
            );
        }
        if let Some(p) = &self.scratch_pool {
            let mut sp = BTreeMap::new();
            sp.insert("hits".into(), Json::Num(p.hits as f64));
            sp.insert("misses".into(), Json::Num(p.misses as f64));
            m.insert("scratch_pool".into(), Json::Obj(sp));
        }
        if let Some(ls) = &self.link_busy_stall {
            m.insert("link_busy_stall".into(), pair_arr(ls));
        }
        if let Some(l) = &self.fg_latency {
            let mut fl = BTreeMap::new();
            fl.insert("count".into(), Json::Num(l.count as f64));
            fl.insert("mean".into(), Json::Num(l.mean));
            fl.insert("p50".into(), Json::Num(l.p50));
            fl.insert("p95".into(), Json::Num(l.p95));
            fl.insert("p99".into(), Json::Num(l.p99));
            fl.insert("max".into(), Json::Num(l.max));
            m.insert("fg_latency".into(), Json::Obj(fl));
        }
        if let Some(x) = self.recovery_slowdown {
            m.insert("recovery_slowdown".into(), Json::Num(x));
        }
        if let Some(f) = &self.faults {
            // shared with `d3ctl chaos --json` via FaultReport::to_json
            m.insert("faults".into(), f.to_json());
        }
        if let Some(t) = &self.trace {
            // shared with `d3ctl trace --json` via TraceSummary::to_json
            m.insert("trace".into(), t.to_json());
        }
        Json::Obj(m)
    }
}

/// Executes a [`FailureScenario`] and measures a [`ScenarioOutcome`].
pub trait RecoveryBackend {
    fn name(&self) -> &'static str;

    fn run(
        &self,
        scenario: &FailureScenario,
        policy: &Arc<dyn Placement>,
        spec: &SystemSpec,
    ) -> Result<ScenarioOutcome>;
}

/// Cross-rack block transfers prescribed by a plan set (backend-free).
pub fn planned_cross_rack_blocks(plans: &[RepairPlan]) -> usize {
    plans.iter().map(|p| p.cross_rack_blocks()).sum()
}

/// Degraded-read plans for the degraded requests of a generated sequence,
/// through a table the caller already built — the backends' burst path
/// derives its plans in one pass from the request sequence it already
/// holds instead of regenerating sequence and table per use.
pub fn degraded_read_plans(
    table: &PlacementTable,
    reqs: &[Request],
    seed: u64,
) -> Vec<RepairPlan> {
    reqs.iter()
        .filter_map(|r| match r.class {
            RequestClass::DegradedRead { stripe, block } => {
                Some(plan_degraded_read(table, stripe, block, r.client, seed))
            }
            _ => None,
        })
        .collect()
}

/// The distinct racks of a failure set, in first-seen order — the racks
/// both backends exclude from λ.
pub fn distinct_racks(failed: &[Location]) -> Vec<u32> {
    let mut racks = Vec::new();
    for f in failed {
        if !racks.contains(&f.rack) {
            racks.push(f.rack);
        }
    }
    racks
}

/// Run one scenario on every backend in `backends`, printing each report.
pub fn run_cross_backend(
    scenario: &FailureScenario,
    policy: &Arc<dyn Placement>,
    spec: &SystemSpec,
    backends: &[&dyn RecoveryBackend],
) -> Result<Vec<ScenarioOutcome>> {
    let mut outcomes = Vec::with_capacity(backends.len());
    for backend in backends {
        let out = backend.run(scenario, policy, spec)?;
        out.print();
        outcomes.push(out);
    }
    Ok(outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::CodeSpec;
    use crate::placement::D3Placement;
    use crate::topology::ClusterSpec;

    fn policy() -> Arc<dyn Placement> {
        Arc::new(
            D3Placement::new(CodeSpec::Rs { k: 6, m: 3 }, ClusterSpec::new(8, 3)).unwrap(),
        )
    }

    #[test]
    fn failure_sets_are_deterministic_and_well_formed() {
        let p = policy();
        let single = FailureScenario::single_node(120, 7);
        assert_eq!(
            single.failed_nodes(p.as_ref()),
            single.failed_nodes(p.as_ref())
        );
        assert_eq!(single.failed_nodes(p.as_ref()).len(), 1);

        let multi = FailureScenario::multi_node(3, 120, 7);
        let nodes = multi.failed_nodes(p.as_ref());
        assert_eq!(nodes.len(), 3);
        let set: std::collections::HashSet<Location> = nodes.iter().copied().collect();
        assert_eq!(set.len(), 3, "failures must be distinct");

        let rack = FailureScenario::rack_failure(2, 120, 7);
        let nodes = rack.failed_nodes(p.as_ref());
        assert_eq!(nodes.len(), 3);
        assert!(nodes.iter().all(|l| l.rack == 2));
    }

    #[test]
    fn recovery_plans_cover_every_lost_block() {
        let p = policy();
        let scenario = FailureScenario::multi_node(2, 100, 11);
        let (failed, plans) = scenario.recovery_plans(&p).unwrap();
        let failed_set: std::collections::HashSet<Location> =
            failed.iter().copied().collect();
        let mut expected = 0usize;
        for sid in 0..100u64 {
            expected += p
                .stripe(sid)
                .locs
                .iter()
                .filter(|l| failed_set.contains(l))
                .count();
        }
        assert_eq!(plans.len(), expected);
        assert!(expected > 0);
    }

    #[test]
    fn burst_samples_target_lost_blocks_only() {
        let p = policy();
        let scenario = FailureScenario::degraded_burst(16, 100, 3);
        let (failed, samples) = scenario.burst_samples(&p).unwrap();
        assert_eq!(samples.len(), 16);
        for (sid, block, client) in samples {
            assert_eq!(p.stripe(sid).locs[block], failed);
            assert_ne!(client, failed);
        }
    }

    #[test]
    fn failed_node_probe_covers_stripes_beyond_the_old_200_window() {
        // Regression for the fixed probe (ISSUE 5): on a sparse aperiodic
        // layout the old 200-stripe window could pick a node whose blocks
        // all lie beyond it, making the degraded burst bail with "holds
        // no blocks". The period-aware probe must always pick a holder.
        use crate::placement::RddPlacement;
        let cluster = ClusterSpec::new(20, 20); // 400 nodes
        let stripes = 260u64; // 780 block slots: many nodes hold few/late
        for seed in 0..24u64 {
            let p: Arc<dyn Placement> = Arc::new(RddPlacement::new(
                CodeSpec::Rs { k: 2, m: 1 },
                cluster,
                seed,
            ));
            let scenario = FailureScenario::degraded_burst(4, stripes, seed);
            let failed = scenario.failed_nodes(p.as_ref())[0];
            let holds = (0..stripes).any(|sid| p.stripe(sid).locs.contains(&failed));
            assert!(holds, "seed {seed}: picked node {failed} holds no blocks");
            let (f, samples) = scenario.burst_samples(&p).unwrap();
            assert_eq!(f, failed);
            assert_eq!(samples.len(), 4);
        }
    }

    #[test]
    fn periodic_probe_uses_one_period_and_still_finds_holders() {
        let p = policy();
        let period = p.period().expect("D³ is periodic");
        let stripes = period * 3 + 7; // well beyond 200 for this layout
        let scenario = FailureScenario::single_node(stripes, 11);
        let failed = scenario.failed_nodes(p.as_ref())[0];
        assert!(
            (0..stripes).any(|sid| p.stripe(sid).locs.contains(&failed)),
            "failed node {failed} holds nothing"
        );
        // deterministic across calls
        assert_eq!(
            scenario.failed_nodes(p.as_ref()),
            scenario.failed_nodes(p.as_ref())
        );
    }

    #[test]
    fn fg_requests_derive_from_kind_and_are_deterministic() {
        let p = policy();
        let burst = FailureScenario::degraded_burst(8, 60, 2);
        let (spec, reqs) = burst.fg_requests(&p).unwrap().expect("burst has fg");
        assert_eq!(spec.requests, 8);
        assert_eq!(reqs.len(), 8);
        assert_eq!(
            reqs,
            burst.fg_requests(&p).unwrap().unwrap().1,
            "request sequence must be reproducible"
        );
        let mix = FailureScenario::frontend_mix("terasort", 60, 2);
        let (spec, reqs) = mix.fg_requests(&p).unwrap().expect("mix has fg");
        assert_eq!(reqs.len(), spec.requests);
        assert!(FailureScenario::frontend_mix("bogus", 60, 2)
            .fg_requests(&p)
            .is_err());
        let plain = FailureScenario::single_node(60, 2);
        assert!(plain.fg_requests(&p).unwrap().is_none());
        // any kind becomes mixed-load via with_fg
        let mixed = FailureScenario::single_node(60, 2).with_fg(crate::client::FgSpec::reads(
            10,
            crate::client::ArrivalModel::Open { rate_rps: 50.0 },
        ));
        assert_eq!(mixed.fg_requests(&p).unwrap().unwrap().1.len(), 10);
    }

    #[test]
    fn outcome_json_includes_fg_latency_block() {
        let out = ScenarioOutcome {
            backend: "sim",
            scenario: "single-node".into(),
            policy: "d3".into(),
            blocks: 3,
            bytes: 3 << 20,
            seconds: 1.5,
            throughput_mb_s: 2.0,
            lambda: 0.1,
            rack_cross_bytes: vec![(1, 2), (3, 4)],
            planned_cross_rack_blocks: 5,
            degraded_read_mean_s: None,
            frontend_seconds: Some(9.0),
            worker_utilization: Some(vec![0.5, 0.25]),
            scratch_pool: None,
            link_busy_stall: Some(vec![(0.5, 0.0)]),
            fg_latency: Some(crate::metrics::summarize(&[0.1, 0.2, 0.3, 0.4])),
            recovery_slowdown: Some(1.25),
            faults: Some(crate::metrics::FaultReport {
                drops: 2,
                corrupts: 1,
                retries: 3,
                ..Default::default()
            }),
            trace: Some(trace::TraceSummary {
                failures: 4,
                rounds: 3,
                blocks_repaired: 40,
                lost_stripes: 0,
                arrival_mb_s: 1.5,
                sustained_mb_s: 6.0,
                backlog_peak: 18,
                horizon_s: 3600.0,
                ..Default::default()
            }),
        };
        let j = out.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("blocks").and_then(Json::as_usize), Some(3));
        let fl = parsed.get("fg_latency").expect("fg_latency block");
        assert_eq!(fl.get("count").and_then(Json::as_usize), Some(4));
        assert!(fl.get("p99").and_then(Json::as_f64).is_some());
        assert_eq!(
            parsed.get("recovery_slowdown").and_then(Json::as_f64),
            Some(1.25)
        );
        let fj = parsed.get("faults").expect("faults block");
        assert_eq!(fj.get("drops").and_then(Json::as_usize), Some(2));
        assert_eq!(fj.get("retries").and_then(Json::as_usize), Some(3));
        let tj = parsed.get("trace").expect("trace block");
        assert_eq!(tj.get("failures").and_then(Json::as_usize), Some(4));
        assert_eq!(tj.get("sustained_mb_s").and_then(Json::as_f64), Some(6.0));
    }
}
