//! The failure-scenario engine (DESIGN.md §5): first-class, backend-
//! agnostic failure scenarios.
//!
//! A [`FailureScenario`] describes *what goes wrong* — which nodes die,
//! what load competes with recovery — independently of *how the outcome is
//! measured*. A [`RecoveryBackend`] executes a scenario and reports a
//! [`ScenarioOutcome`]; the two implementations are
//!
//! * [`crate::sim::recovery::SimBackend`] — the fluid discrete-event
//!   simulator (simulated seconds, analytic port loads), and
//! * [`crate::cluster::ClusterBackend`] — the in-process MiniCluster
//!   (real bytes through throttled links, wall-clock seconds),
//!
//! so every scenario is cross-checkable: the same failure set and the same
//! repair plans drive both, and backend-independent quantities (blocks
//! rebuilt, planned cross-rack block transfers, relative cross-rack bytes
//! between policies) must agree.
//!
//! The paper evaluates single-node failures only; the scenario kinds add
//! the correlated failures that dominate production repair traffic
//! (multi-node, whole-rack — see Rashmi et al., arXiv:1309.0186) plus the
//! front-end-load and degraded-read-burst mixes of §6.2.3–§6.2.4.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::placement::{Placement, PlacementTable};
use crate::recovery::multi::scenario_recovery_plans;
use crate::recovery::plan::{plan_degraded_read, RepairPlan};
use crate::topology::{Location, SystemSpec};
use crate::util::Rng;

/// What goes wrong.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ScenarioKind {
    /// One node fails (the paper's §6 setting).
    SingleNode,
    /// `failures` nodes fail concurrently (correlated failure).
    MultiNode { failures: usize },
    /// Every node of one rack fails (switch/power-domain failure).
    RackFailure { rack: u32 },
    /// One node fails while a front-end workload runs (paper Exp 11).
    FrontendMix { workload: String },
    /// One node fails and `reads` clients immediately degraded-read lost
    /// blocks (paper Exp 3, but as a concurrent burst).
    DegradedBurst { reads: usize },
}

/// A failure scenario: the kind, the stored-stripe population it hits, and
/// the seed that makes every derived choice (failed nodes, read samples)
/// deterministic and identical across backends.
#[derive(Clone, Debug)]
pub struct FailureScenario {
    pub kind: ScenarioKind,
    pub stripes: u64,
    pub seed: u64,
}

impl FailureScenario {
    pub fn single_node(stripes: u64, seed: u64) -> FailureScenario {
        FailureScenario { kind: ScenarioKind::SingleNode, stripes, seed }
    }

    pub fn multi_node(failures: usize, stripes: u64, seed: u64) -> FailureScenario {
        FailureScenario { kind: ScenarioKind::MultiNode { failures }, stripes, seed }
    }

    pub fn rack_failure(rack: u32, stripes: u64, seed: u64) -> FailureScenario {
        FailureScenario { kind: ScenarioKind::RackFailure { rack }, stripes, seed }
    }

    pub fn frontend_mix(workload: &str, stripes: u64, seed: u64) -> FailureScenario {
        FailureScenario {
            kind: ScenarioKind::FrontendMix { workload: workload.to_string() },
            stripes,
            seed,
        }
    }

    pub fn degraded_burst(reads: usize, stripes: u64, seed: u64) -> FailureScenario {
        FailureScenario { kind: ScenarioKind::DegradedBurst { reads }, stripes, seed }
    }

    /// Short label, e.g. `single-node`, `multi-node-2`, `rack-failure-0`.
    pub fn name(&self) -> String {
        match &self.kind {
            ScenarioKind::SingleNode => "single-node".into(),
            ScenarioKind::MultiNode { failures } => format!("multi-node-{failures}"),
            ScenarioKind::RackFailure { rack } => format!("rack-failure-{rack}"),
            ScenarioKind::FrontendMix { workload } => format!("frontend-mix-{workload}"),
            ScenarioKind::DegradedBurst { reads } => format!("degraded-burst-{reads}"),
        }
    }

    /// The deterministic failure set under `policy`'s topology. Single-node
    /// kinds pick a seed-keyed node that actually stores blocks (so the
    /// scenario is never vacuous); multi-node samples distinct nodes;
    /// rack failure takes the whole rack.
    pub fn failed_nodes(&self, policy: &dyn Placement) -> Vec<Location> {
        let cluster = policy.cluster();
        let count = cluster.node_count();
        match &self.kind {
            ScenarioKind::SingleNode
            | ScenarioKind::FrontendMix { .. }
            | ScenarioKind::DegradedBurst { .. } => {
                let mut rng = Rng::keyed(self.seed, 0x0fa1_1ed, 0);
                let start = rng.below(count);
                let probe = self.stripes.min(200);
                for off in 0..count {
                    let loc = cluster.unflat((start + off) % count);
                    let holds = (0..probe)
                        .any(|sid| policy.stripe(sid).locs.contains(&loc));
                    if holds {
                        return vec![loc];
                    }
                }
                vec![cluster.unflat(start)]
            }
            ScenarioKind::MultiNode { failures } => {
                let mut rng = Rng::keyed(self.seed, 0x0fa1_1ed, 1);
                let want = (*failures).clamp(1, count.saturating_sub(1));
                rng.sample_indices(count, want)
                    .into_iter()
                    .map(|i| cluster.unflat(i))
                    .collect()
            }
            ScenarioKind::RackFailure { rack } => {
                let rack = (*rack as usize).min(cluster.racks - 1);
                (0..cluster.nodes_per_rack)
                    .map(|j| Location::new(rack, j))
                    .collect()
            }
        }
    }

    /// Repair plans for this scenario's failure set, built through a
    /// table-backed placement lookup (DESIGN.md §7). Returns
    /// `(failed nodes, plans)`; both backends call this, so they always
    /// execute the *same* plans.
    pub fn recovery_plans(
        &self,
        policy: &Arc<dyn Placement>,
    ) -> Result<(Vec<Location>, Vec<RepairPlan>)> {
        let failed = self.failed_nodes(policy.as_ref());
        let table = PlacementTable::build(policy.clone(), self.stripes);
        let plans = scenario_recovery_plans(&table, self.stripes, &failed, self.seed)?;
        Ok((failed, plans))
    }

    /// For [`ScenarioKind::DegradedBurst`]: the failed node and the
    /// seed-keyed `(stripe, block, client)` read samples, identical across
    /// backends.
    pub fn burst_samples(
        &self,
        policy: &Arc<dyn Placement>,
    ) -> Result<(Location, Vec<(u64, usize, Location)>)> {
        let ScenarioKind::DegradedBurst { reads } = &self.kind else {
            bail!("burst_samples on a non-burst scenario");
        };
        let reads = *reads;
        let cluster = policy.cluster();
        let failed = self.failed_nodes(policy.as_ref())[0];
        let table = PlacementTable::build(policy.clone(), self.stripes);
        let mut lost: Vec<(u64, usize)> = Vec::new();
        for sid in 0..self.stripes {
            let sp = table.stripe(sid);
            for (bi, &loc) in sp.locs.iter().enumerate() {
                if loc == failed {
                    lost.push((sid, bi));
                }
            }
        }
        if lost.is_empty() {
            bail!("degraded burst: failed node {failed} holds no blocks");
        }
        let mut rng = Rng::keyed(self.seed, 0xb125_7, 2);
        let mut samples = Vec::with_capacity(reads);
        for _ in 0..reads {
            let (sid, block) = lost[rng.below(lost.len())];
            let client = loop {
                let c = cluster.unflat(rng.below(cluster.node_count()));
                if c != failed {
                    break c;
                }
            };
            samples.push((sid, block, client));
        }
        Ok((failed, samples))
    }

    /// Degraded-read plans for the burst samples (fluid backend).
    pub fn burst_read_plans(
        &self,
        policy: &Arc<dyn Placement>,
    ) -> Result<(Location, Vec<RepairPlan>)> {
        let (failed, samples) = self.burst_samples(policy)?;
        let table = PlacementTable::build(policy.clone(), self.stripes);
        let plans = samples
            .into_iter()
            .map(|(sid, block, client)| {
                plan_degraded_read(&table, sid, block, client, self.seed)
            })
            .collect();
        Ok((failed, plans))
    }
}

/// What a backend measured for one scenario run.
#[derive(Clone, Debug)]
pub struct ScenarioOutcome {
    /// Backend that produced this outcome (`sim` or `cluster`).
    pub backend: &'static str,
    /// Scenario label ([`FailureScenario::name`]).
    pub scenario: String,
    /// Placement policy name.
    pub policy: String,
    /// Blocks rebuilt (node/rack kinds) or degraded reads served (burst).
    pub blocks: usize,
    /// Bytes rebuilt/served at the backend's block size.
    pub bytes: u64,
    /// Recovery time: simulated seconds (sim) or wall-clock (cluster).
    pub seconds: f64,
    /// bytes / seconds, MB/s.
    pub throughput_mb_s: f64,
    /// Load-imbalance λ over surviving racks' cross-rack port loads.
    pub lambda: f64,
    /// Per-rack cross-rack bytes (up, down) during the scenario.
    pub rack_cross_bytes: Vec<(u64, u64)>,
    /// Whole-block cross-rack transfers the plans prescribe —
    /// backend-independent (the paper's "cross-rack accessed blocks").
    pub planned_cross_rack_blocks: usize,
    /// Mean degraded-read latency (burst kind only).
    pub degraded_read_mean_s: Option<f64>,
    /// Front-end workload completion time (frontend-mix kind only).
    pub frontend_seconds: Option<f64>,
    /// Per-worker busy fraction of the recovery executor (cluster backend
    /// recovery kinds only; the fluid backend has no discrete workers).
    pub worker_utilization: Option<Vec<f64>>,
    /// Scratch-buffer-pool hit/miss totals of the recovery executor's
    /// worker pools (cluster backend recovery kinds only) — near-1.0 hit
    /// rates mean the data path ran allocation-free (DESIGN.md §9).
    pub scratch_pool: Option<crate::metrics::PoolStats>,
    /// Per-rack-link (busy, stall) seconds during the scenario
    /// (DESIGN.md §10). The cluster backend measures both from its link
    /// meters; the fluid backend derives busy from port loads at the
    /// configured rate and reports zero stall (max-min fair sharing has
    /// no queueing in front of the ports).
    pub link_busy_stall: Option<Vec<(f64, f64)>>,
}

impl ScenarioOutcome {
    /// Total cross-rack bytes (sum of every rack's upstream port).
    pub fn total_cross_rack_bytes(&self) -> u64 {
        self.rack_cross_bytes.iter().map(|&(up, _)| up).sum()
    }

    /// Human-readable report (the `d3ctl scenario` output).
    pub fn print(&self) {
        println!(
            "[{}] {} · {}: {} blocks ({:.1} MB) in {:.2} s → {:.1} MB/s, λ={:.3}",
            self.backend,
            self.scenario,
            self.policy,
            self.blocks,
            self.bytes as f64 / 1e6,
            self.seconds,
            self.throughput_mb_s,
            self.lambda
        );
        println!(
            "  planned cross-rack block transfers: {} · total cross-rack bytes: {:.1} MB",
            self.planned_cross_rack_blocks,
            self.total_cross_rack_bytes() as f64 / 1e6
        );
        let per_rack: Vec<String> = self
            .rack_cross_bytes
            .iter()
            .enumerate()
            .map(|(r, &(up, down))| {
                format!("r{r} {:.1}/{:.1}", up as f64 / 1e6, down as f64 / 1e6)
            })
            .collect();
        println!("  per-rack cross bytes up/down (MB): {}", per_rack.join("  "));
        if let Some(d) = self.degraded_read_mean_s {
            println!("  mean degraded-read latency: {d:.2} s");
        }
        if let Some(f) = self.frontend_seconds {
            println!("  front-end workload completion: {f:.1} s");
        }
        if let Some(u) = &self.worker_utilization {
            let cells: Vec<String> =
                u.iter().map(|x| format!("{:.0}%", x * 100.0)).collect();
            println!("  per-worker utilization: {}", cells.join(" "));
        }
        if let Some(p) = &self.scratch_pool {
            println!(
                "  scratch pool: {} hits / {} misses ({:.0}% reuse)",
                p.hits,
                p.misses,
                p.hit_rate() * 100.0
            );
        }
        if let Some(ls) = &self.link_busy_stall {
            let cells: Vec<String> = ls
                .iter()
                .enumerate()
                .map(|(r, &(b, s))| format!("r{r} {b:.2}/{s:.2}"))
                .collect();
            println!("  per-rack-link busy/stall (s): {}", cells.join("  "));
        }
    }
}

/// Executes a [`FailureScenario`] and measures a [`ScenarioOutcome`].
pub trait RecoveryBackend {
    fn name(&self) -> &'static str;

    fn run(
        &self,
        scenario: &FailureScenario,
        policy: &Arc<dyn Placement>,
        spec: &SystemSpec,
    ) -> Result<ScenarioOutcome>;
}

/// Cross-rack block transfers prescribed by a plan set (backend-free).
pub fn planned_cross_rack_blocks(plans: &[RepairPlan]) -> usize {
    plans.iter().map(|p| p.cross_rack_blocks()).sum()
}

/// The distinct racks of a failure set, in first-seen order — the racks
/// both backends exclude from λ.
pub fn distinct_racks(failed: &[Location]) -> Vec<u32> {
    let mut racks = Vec::new();
    for f in failed {
        if !racks.contains(&f.rack) {
            racks.push(f.rack);
        }
    }
    racks
}

/// Run one scenario on every backend in `backends`, printing each report.
pub fn run_cross_backend(
    scenario: &FailureScenario,
    policy: &Arc<dyn Placement>,
    spec: &SystemSpec,
    backends: &[&dyn RecoveryBackend],
) -> Result<Vec<ScenarioOutcome>> {
    let mut outcomes = Vec::with_capacity(backends.len());
    for backend in backends {
        let out = backend.run(scenario, policy, spec)?;
        out.print();
        outcomes.push(out);
    }
    Ok(outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::CodeSpec;
    use crate::placement::D3Placement;
    use crate::topology::ClusterSpec;

    fn policy() -> Arc<dyn Placement> {
        Arc::new(
            D3Placement::new(CodeSpec::Rs { k: 6, m: 3 }, ClusterSpec::new(8, 3)).unwrap(),
        )
    }

    #[test]
    fn failure_sets_are_deterministic_and_well_formed() {
        let p = policy();
        let single = FailureScenario::single_node(120, 7);
        assert_eq!(
            single.failed_nodes(p.as_ref()),
            single.failed_nodes(p.as_ref())
        );
        assert_eq!(single.failed_nodes(p.as_ref()).len(), 1);

        let multi = FailureScenario::multi_node(3, 120, 7);
        let nodes = multi.failed_nodes(p.as_ref());
        assert_eq!(nodes.len(), 3);
        let set: std::collections::HashSet<Location> = nodes.iter().copied().collect();
        assert_eq!(set.len(), 3, "failures must be distinct");

        let rack = FailureScenario::rack_failure(2, 120, 7);
        let nodes = rack.failed_nodes(p.as_ref());
        assert_eq!(nodes.len(), 3);
        assert!(nodes.iter().all(|l| l.rack == 2));
    }

    #[test]
    fn recovery_plans_cover_every_lost_block() {
        let p = policy();
        let scenario = FailureScenario::multi_node(2, 100, 11);
        let (failed, plans) = scenario.recovery_plans(&p).unwrap();
        let failed_set: std::collections::HashSet<Location> =
            failed.iter().copied().collect();
        let mut expected = 0usize;
        for sid in 0..100u64 {
            expected += p
                .stripe(sid)
                .locs
                .iter()
                .filter(|l| failed_set.contains(l))
                .count();
        }
        assert_eq!(plans.len(), expected);
        assert!(expected > 0);
    }

    #[test]
    fn burst_samples_target_lost_blocks_only() {
        let p = policy();
        let scenario = FailureScenario::degraded_burst(16, 100, 3);
        let (failed, samples) = scenario.burst_samples(&p).unwrap();
        assert_eq!(samples.len(), 16);
        for (sid, block, client) in samples {
            assert_eq!(p.stripe(sid).locs[block], failed);
            assert_ne!(client, failed);
        }
    }
}
