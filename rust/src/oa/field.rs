//! Small finite fields GF(p^e) for orthogonal-array construction.
//!
//! D³ needs OA(n, ·) for n = nodes-per-rack and n = rack-count — small
//! numbers (≤ ~1024). We build GF(p^e) generically: find an irreducible
//! monic polynomial of degree e over Z_p by search, then precompute full
//! add/mul tables indexed by element id (digits base p).

/// A finite field GF(p^e) with dense operation tables.
#[derive(Clone, Debug)]
pub struct PrimePowerField {
    pub p: u64,
    pub e: u32,
    /// Field order p^e.
    pub n: usize,
    add_t: Vec<u16>,
    mul_t: Vec<u16>,
}

/// Integer factorization into (prime, exponent) pairs, ascending primes.
pub fn factorize(mut n: u64) -> Vec<(u64, u32)> {
    let mut out = Vec::new();
    let mut d = 2u64;
    while d * d <= n {
        if n % d == 0 {
            let mut e = 0;
            while n % d == 0 {
                n /= d;
                e += 1;
            }
            out.push((d, e));
        }
        d += 1;
    }
    if n > 1 {
        out.push((n, 1));
    }
    out
}

/// True if n is a prime power (single factor).
pub fn is_prime_power(n: u64) -> bool {
    n >= 2 && factorize(n).len() == 1
}

// -------- Z_p[x] helpers (coefficient vectors, lowest degree first) --------

fn poly_deg(a: &[u64]) -> usize {
    a.iter().rposition(|&c| c != 0).unwrap_or(0)
}

/// Remainder of a mod b over Z_p (b monic-ish: leading coeff inverted).
fn poly_rem(a: &[u64], b: &[u64], p: u64) -> Vec<u64> {
    let mut r = a.to_vec();
    let db = poly_deg(b);
    let lead_inv = mod_inv(b[db], p);
    while poly_deg(&r) >= db && r.iter().any(|&c| c != 0) {
        let dr = poly_deg(&r);
        if dr < db {
            break;
        }
        let f = (r[dr] * lead_inv) % p;
        if f == 0 {
            break;
        }
        let shift = dr - db;
        for i in 0..=db {
            let sub = (f * b[i]) % p;
            r[i + shift] = (r[i + shift] + p - sub) % p;
        }
    }
    r.truncate(db.max(1));
    r.resize(db.max(1), 0);
    r
}

fn mod_inv(a: u64, p: u64) -> u64 {
    // Fermat: p prime
    mod_pow(a % p, p - 2, p)
}

fn mod_pow(mut a: u64, mut e: u64, p: u64) -> u64 {
    let mut acc = 1u64;
    a %= p;
    while e > 0 {
        if e & 1 == 1 {
            acc = acc * a % p;
        }
        a = a * a % p;
        e >>= 1;
    }
    acc
}

/// Decode element id into digit vector (degree-e poly over Z_p).
fn digits(mut id: usize, p: u64, e: u32) -> Vec<u64> {
    let mut d = vec![0u64; e as usize];
    for slot in d.iter_mut() {
        *slot = (id as u64) % p;
        id /= p as usize;
    }
    d
}

fn undigits(d: &[u64], p: u64) -> usize {
    let mut id = 0usize;
    for &c in d.iter().rev() {
        id = id * p as usize + c as usize;
    }
    id
}

/// Find a monic irreducible polynomial of degree e over Z_p (brute force —
/// fields here are tiny). Returned lowest-first with leading coeff 1.
fn find_irreducible(p: u64, e: u32) -> Vec<u64> {
    assert!(e >= 2);
    let e = e as usize;
    // iterate over the non-leading coefficients
    let count = (p as usize).pow(e as u32);
    'candidates: for lower in 0..count {
        let mut f = digits(lower, p, e as u32);
        f.push(1); // monic, degree e
        if f[0] == 0 {
            continue; // divisible by x
        }
        // trial divide by every monic poly of degree 1..=e/2
        for d in 1..=e / 2 {
            let dcount = (p as usize).pow(d as u32);
            for lo in 0..dcount {
                let mut g = digits(lo, p, d as u32);
                g.push(1);
                let r = poly_rem(&f, &g, p);
                if r.iter().all(|&c| c == 0) {
                    continue 'candidates;
                }
            }
        }
        return f;
    }
    unreachable!("no irreducible polynomial found for p={p} e={e}");
}

impl PrimePowerField {
    /// Build GF(n) for prime-power n. Panics otherwise.
    pub fn new(n: usize) -> PrimePowerField {
        let factors = factorize(n as u64);
        assert!(factors.len() == 1, "GF({n}): not a prime power");
        let (p, e) = factors[0];
        let mut add_t = vec![0u16; n * n];
        let mut mul_t = vec![0u16; n * n];
        if e == 1 {
            for a in 0..n {
                for b in 0..n {
                    add_t[a * n + b] = ((a + b) % n) as u16;
                    mul_t[a * n + b] = (a * b % n) as u16;
                }
            }
        } else {
            let modulus = find_irreducible(p, e);
            for a in 0..n {
                let da = digits(a, p, e);
                for b in 0..n {
                    let db = digits(b, p, e);
                    // add
                    let sum: Vec<u64> =
                        da.iter().zip(&db).map(|(&x, &y)| (x + y) % p).collect();
                    add_t[a * n + b] = undigits(&sum, p) as u16;
                    // mul: schoolbook then reduce
                    let mut prod = vec![0u64; 2 * e as usize];
                    for (i, &x) in da.iter().enumerate() {
                        if x == 0 {
                            continue;
                        }
                        for (j, &y) in db.iter().enumerate() {
                            prod[i + j] = (prod[i + j] + x * y) % p;
                        }
                    }
                    let r = poly_rem(&prod, &modulus, p);
                    let mut rr = r;
                    rr.resize(e as usize, 0);
                    mul_t[a * n + b] = undigits(&rr, p) as u16;
                }
            }
        }
        PrimePowerField { p, e, n, add_t, mul_t }
    }

    #[inline]
    pub fn add(&self, a: usize, b: usize) -> usize {
        self.add_t[a * self.n + b] as usize
    }

    #[inline]
    pub fn mul(&self, a: usize, b: usize) -> usize {
        self.mul_t[a * self.n + b] as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factorize_basics() {
        assert_eq!(factorize(360), vec![(2, 3), (3, 2), (5, 1)]);
        assert_eq!(factorize(2), vec![(2, 1)]);
        assert_eq!(factorize(1024), vec![(2, 10)]);
        assert!(is_prime_power(27));
        assert!(is_prime_power(1021));
        assert!(!is_prime_power(6));
        assert!(!is_prime_power(1));
    }

    fn check_field_axioms(f: &PrimePowerField) {
        let n = f.n;
        // additive/multiplicative identity
        for a in 0..n {
            assert_eq!(f.add(a, 0), a);
            assert_eq!(f.mul(a, 1), a);
            assert_eq!(f.mul(a, 0), 0);
        }
        // commutativity + associativity on a sample
        for a in 0..n.min(16) {
            for b in 0..n.min(16) {
                assert_eq!(f.add(a, b), f.add(b, a));
                assert_eq!(f.mul(a, b), f.mul(b, a));
                for c in 0..n.min(8) {
                    assert_eq!(f.mul(a, f.mul(b, c)), f.mul(f.mul(a, b), c));
                    assert_eq!(
                        f.mul(a, f.add(b, c)),
                        f.add(f.mul(a, b), f.mul(a, c))
                    );
                }
            }
        }
        // every nonzero element invertible: row a of mul table hits 1
        for a in 1..n {
            assert!(
                (0..n).any(|b| f.mul(a, b) == 1),
                "no inverse for {a} in GF({n})"
            );
        }
        // addition forms a group: each row of add table is a permutation
        for a in 0..n {
            let mut seen = vec![false; n];
            for b in 0..n {
                let v = f.add(a, b);
                assert!(!seen[v]);
                seen[v] = true;
            }
        }
    }

    #[test]
    fn prime_fields() {
        for n in [2, 3, 5, 7, 11, 13] {
            check_field_axioms(&PrimePowerField::new(n));
        }
    }

    #[test]
    fn prime_power_fields() {
        for n in [4, 8, 9, 16, 25, 27, 32, 49] {
            check_field_axioms(&PrimePowerField::new(n));
        }
    }

    #[test]
    #[should_panic(expected = "not a prime power")]
    fn composite_rejected() {
        PrimePowerField::new(6);
    }
}
