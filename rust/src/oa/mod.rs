//! Orthogonal arrays OA(n, k) — the combinatorial core of D³ (paper §2.4).
//!
//! Definition 1: an OA(n, k) is an n² × k array over an n-symbol alphabet
//! such that within any two columns every ordered pair of symbols occurs in
//! exactly one row.
//!
//! Construction: for prime-power n we use the classical linear family over
//! GF(n) — row (i, j), column c holds `i·x_c + j` where x_c is the c-th
//! field element. This yields OA(n, n) whose **first n rows (i = 0) are
//! identical across all columns** (entry = j), exactly the canonical form
//! §4.5 requires (those rows are dropped to form 𝓜, paper §4.3). For
//! composite n we take the MacNeish product of the prime-power component
//! arrays, which preserves both the OA property and the identical-prefix
//! form, giving OA(n, min pᵢᵉⁱ) columns (Theorem 1).

pub mod field;

use field::{factorize, PrimePowerField};

/// An orthogonal array OA(n, cols): n² rows over symbols 0..n.
#[derive(Clone, Debug)]
pub struct OrthogonalArray {
    n: usize,
    cols: usize,
    storage: Storage,
}

/// Dense arrays are fast to index but cost n² × cols entries; past
/// [`DENSE_LIMIT_ENTRIES`] we keep only the component fields and evaluate
/// the linear form `i·x_c + j` per lookup, so OA(10000, ·) costs kilobytes
/// instead of gigabytes.
#[derive(Clone, Debug)]
enum Storage {
    /// Row-major n² × cols.
    Dense(Vec<u16>),
    Lazy {
        comps: Vec<PrimePowerField>,
        orders: Vec<usize>,
    },
}

/// Entry-count threshold (n² × cols) above which construction switches to
/// lazy evaluation: 2²² entries = 8 MiB of u16, cheap enough to keep dense.
const DENSE_LIMIT_ENTRIES: usize = 1 << 22;

/// Max distinct prime factors of any n ≤ u16::MAX (2·3·5·7·11·13 = 30030,
/// adding 17 exceeds 65535) — bounds the stack scratch in `linear_entry`.
const MAX_COMPONENTS: usize = 8;

/// The linear-construction entry for row = i·n + j, column c: per component
/// field f_t, digit = f_t.add(f_t.mul(i_t, x_c), j_t), recomposed in the
/// same mixed radix. Matches `to_mixed` (most-significant component first)
/// and `from_mixed` (ascending) exactly — the dense table is filled from
/// this same function, so Dense and Lazy agree bit-for-bit.
fn linear_entry(
    comps: &[PrimePowerField],
    orders: &[usize],
    n: usize,
    row: usize,
    col: usize,
) -> usize {
    let (i, j) = (row / n, row % n);
    let m = orders.len();
    debug_assert!(m <= MAX_COMPONENTS);
    let mut di = [0usize; MAX_COMPONENTS];
    let mut dj = [0usize; MAX_COMPONENTS];
    let (mut vi, mut vj) = (i, j);
    for t in (0..m).rev() {
        di[t] = vi % orders[t];
        vi /= orders[t];
        dj[t] = vj % orders[t];
        vj /= orders[t];
    }
    // Column id is uniform across components (cols ≤ min order), so x_c = col
    // in every component.
    let mut v = 0;
    for (t, f) in comps.iter().enumerate() {
        v = v * orders[t] + f.add(f.mul(di[t], col), dj[t]);
    }
    v
}

/// Errors from OA construction.
#[derive(Debug, thiserror::Error)]
pub enum OaError {
    #[error("OA(n={n}, cols={cols}): need 2 <= cols <= {max} (Theorem 1 bound for n={n})")]
    TooManyColumns { n: usize, cols: usize, max: usize },
    #[error("OA(n={n}): n must be >= 2")]
    TooSmall { n: usize },
}

/// Maximum column count our construction supports for a given n
/// (Theorem 1: min pᵢᵉⁱ over the prime-power factorization; = n for
/// prime powers).
pub fn max_columns(n: usize) -> usize {
    if n < 2 {
        return 0;
    }
    factorize(n as u64)
        .iter()
        .map(|&(p, e)| (p as usize).pow(e))
        .min()
        .unwrap()
}

impl OrthogonalArray {
    /// Construct OA(n, cols) in canonical form (first n rows identical).
    /// Dense-materialized up to [`DENSE_LIMIT_ENTRIES`] total entries,
    /// lazily evaluated above it.
    pub fn construct(n: usize, cols: usize) -> Result<OrthogonalArray, OaError> {
        Self::construct_with_limit(n, cols, DENSE_LIMIT_ENTRIES)
    }

    fn construct_with_limit(
        n: usize,
        cols: usize,
        dense_limit: usize,
    ) -> Result<OrthogonalArray, OaError> {
        if n < 2 {
            return Err(OaError::TooSmall { n });
        }
        let max = max_columns(n);
        if cols < 2 || cols > max {
            return Err(OaError::TooManyColumns { n, cols, max });
        }
        // Row id = i * n + j with i, j in mixed radix over the components
        // (component fields f_0.. with orders n_0..; id = ((d_0)*n_1 + d_1)..).
        let comps: Vec<PrimePowerField> = factorize(n as u64)
            .iter()
            .map(|&(p, e)| PrimePowerField::new((p as usize).pow(e)))
            .collect();
        let orders: Vec<usize> = comps.iter().map(|f| f.n).collect();
        let storage = if n * n * cols <= dense_limit {
            let mut data = vec![0u16; n * n * cols];
            for row in 0..n * n {
                for c in 0..cols {
                    data[row * cols + c] = linear_entry(&comps, &orders, n, row, c) as u16;
                }
            }
            Storage::Dense(data)
        } else {
            Storage::Lazy { comps, orders }
        };
        Ok(OrthogonalArray { n, cols, storage })
    }

    /// True when entries are computed per lookup instead of materialized.
    pub fn is_lazy(&self) -> bool {
        matches!(self.storage, Storage::Lazy { .. })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn rows(&self) -> usize {
        self.n * self.n
    }

    #[inline]
    pub fn entry(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.rows() && col < self.cols);
        match &self.storage {
            Storage::Dense(data) => data[row * self.cols + col] as usize,
            Storage::Lazy { comps, orders } => linear_entry(comps, orders, self.n, row, col),
        }
    }

    /// Exhaustive check of Definition 1 (O(cols² · n²)).
    pub fn verify(&self) -> bool {
        let n = self.n;
        for c1 in 0..self.cols {
            for c2 in c1 + 1..self.cols {
                let mut seen = vec![false; n * n];
                for r in 0..self.rows() {
                    let key = self.entry(r, c1) * n + self.entry(r, c2);
                    if seen[key] {
                        return false;
                    }
                    seen[key] = true;
                }
                // n² rows, n² pairs, no dup => all present
            }
        }
        true
    }

    /// True if the first n rows are identical across all columns
    /// (canonical form required by §4.3/§4.5).
    pub fn first_rows_identical(&self) -> bool {
        (0..self.n).all(|r| {
            let first = self.entry(r, 0);
            (1..self.cols).all(|c| self.entry(r, c) == first)
        })
    }

    /// The 𝓜 submatrix (paper §4.3): all rows except the first n identical
    /// ones — n(n−1) rows used to place stripe regions. A view over the
    /// parent array (rows offset by n), so it inherits lazy evaluation.
    pub fn m_matrix(&self) -> MMatrix {
        MMatrix { a: self.clone() }
    }
}

/// 𝓜 = OA(r, ·) minus its first r rows: r(r−1) rows addressing stripe
/// regions to racks; the last used column addresses recovered blocks.
#[derive(Clone, Debug)]
pub struct MMatrix {
    a: OrthogonalArray,
}

impl MMatrix {
    pub fn rows(&self) -> usize {
        self.a.n * (self.a.n - 1)
    }

    pub fn cols(&self) -> usize {
        self.a.cols
    }

    #[inline]
    pub fn entry(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.rows() && col < self.cols());
        self.a.entry(row + self.a.n, col)
    }

    /// Within any row, all entries of the used columns are pairwise
    /// distinct? NOT generally true of an OA; but rows of 𝓜 never repeat a
    /// symbol across columns for the linear construction (i ≠ 0 ⇒ the maps
    /// c ↦ i·x_c + j are injective). D³ relies on this: a stripe region's
    /// groups land in distinct racks.
    pub fn row_entries_distinct(&self, row: usize) -> bool {
        let mut seen = vec![false; self.a.n];
        for c in 0..self.cols() {
            let v = self.entry(row, c);
            if seen[v] {
                return false;
            }
            seen[v] = true;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_verify_prime_powers() {
        for n in [2, 3, 4, 5, 7, 8, 9, 11, 16, 25] {
            let oa = OrthogonalArray::construct(n, max_columns(n)).unwrap();
            assert!(oa.verify(), "OA({n}) failed Definition 1");
            assert!(oa.first_rows_identical(), "OA({n}) not canonical");
        }
    }

    #[test]
    fn construct_and_verify_composites() {
        for (n, want_cols) in [(6, 2), (10, 2), (12, 3), (15, 3), (20, 4)] {
            assert_eq!(max_columns(n), want_cols, "n={n}");
            let oa = OrthogonalArray::construct(n, want_cols).unwrap();
            assert!(oa.verify(), "OA({n}) failed Definition 1");
            assert!(oa.first_rows_identical(), "OA({n}) not canonical");
        }
    }

    #[test]
    fn property_1_symbol_counts() {
        // Each column contains each symbol exactly n times (paper Property 1).
        let oa = OrthogonalArray::construct(7, 5).unwrap();
        for c in 0..oa.cols() {
            let mut counts = vec![0usize; 7];
            for r in 0..oa.rows() {
                counts[oa.entry(r, c)] += 1;
            }
            assert!(counts.iter().all(|&x| x == 7));
        }
    }

    #[test]
    fn property_2_conditional_pairs() {
        // Given x in column i, each pair (x, y) appears exactly once in
        // columns (i, j) (paper Property 2).
        let oa = OrthogonalArray::construct(5, 4).unwrap();
        for ci in 0..4 {
            for cj in 0..4 {
                if ci == cj {
                    continue;
                }
                for x in 0..5 {
                    let mut seen = [false; 5];
                    for r in 0..oa.rows() {
                        if oa.entry(r, ci) == x {
                            let y = oa.entry(r, cj);
                            assert!(!seen[y], "dup pair ({x},{y})");
                            seen[y] = true;
                        }
                    }
                    assert!(seen.iter().all(|&s| s), "missing pair from x={x}");
                }
            }
        }
    }

    #[test]
    fn m_matrix_shape_and_distinct_rows() {
        let oa = OrthogonalArray::construct(5, 4).unwrap();
        let m = oa.m_matrix();
        assert_eq!(m.rows(), 20);
        assert_eq!(m.cols(), 4);
        for r in 0..m.rows() {
            assert!(m.row_entries_distinct(r), "row {r} repeats a rack");
        }
    }

    #[test]
    fn m_matrix_column_balance() {
        // Each column of M contains each symbol exactly n-1 times
        // (paper Theorem 2's counting argument).
        let oa = OrthogonalArray::construct(8, 4).unwrap();
        let m = oa.m_matrix();
        for c in 0..m.cols() {
            let mut counts = vec![0usize; 8];
            for r in 0..m.rows() {
                counts[m.entry(r, c)] += 1;
            }
            assert!(counts.iter().all(|&x| x == 7), "col {c}: {counts:?}");
        }
    }

    #[test]
    fn lazy_and_dense_storage_agree_entry_for_entry() {
        // Force both storages at a size where full comparison is cheap.
        for (n, cols) in [(12, 3), (9, 4), (20, 4)] {
            let dense = OrthogonalArray::construct_with_limit(n, cols, usize::MAX).unwrap();
            let lazy = OrthogonalArray::construct_with_limit(n, cols, 0).unwrap();
            assert!(!dense.is_lazy() && lazy.is_lazy());
            for r in 0..dense.rows() {
                for c in 0..cols {
                    assert_eq!(dense.entry(r, c), lazy.entry(r, c), "n={n} ({r},{c})");
                }
            }
            assert!(lazy.verify() && lazy.first_rows_identical());
            let (md, ml) = (dense.m_matrix(), lazy.m_matrix());
            for r in 0..md.rows() {
                for c in 0..cols {
                    assert_eq!(md.entry(r, c), ml.entry(r, c));
                }
            }
        }
    }

    #[test]
    fn large_arrays_go_lazy_automatically() {
        // 1024² × 8 entries > DENSE_LIMIT_ENTRIES: must not materialize.
        let oa = OrthogonalArray::construct(1024, 8).unwrap();
        assert!(oa.is_lazy());
        // Spot-check the linear form against a small dense slice rebuilt at
        // the same n (first rows identical, Property-1 column balance on a
        // sampled column).
        assert!(oa.first_rows_identical());
        let mut counts = vec![0usize; 1024];
        for r in 0..oa.rows() {
            counts[oa.entry(r, 3)] += 1;
        }
        assert!(counts.iter().all(|&x| x == 1024));
    }

    #[test]
    fn errors() {
        assert!(OrthogonalArray::construct(1, 2).is_err());
        assert!(OrthogonalArray::construct(5, 6).is_err());
        assert!(OrthogonalArray::construct(6, 3).is_err()); // max is 2
    }

    #[test]
    fn paper_example_oa_5_4_shape() {
        // Fig 5(d): OA(5, 4), 25 rows, first five rows identical.
        let oa = OrthogonalArray::construct(5, 4).unwrap();
        assert_eq!(oa.rows(), 25);
        for r in 0..5 {
            let v = oa.entry(r, 0);
            assert_eq!(v, r % 5);
            for c in 0..4 {
                assert_eq!(oa.entry(r, c), v);
            }
        }
    }
}
