//! Runtime data path: execute the GF(2^8) coding hot-spot either natively
//! (portable fallback, `gf::combine`) or through the AOT-compiled PJRT
//! artifacts produced by `make artifacts` (`python/compile/aot.py`).
//!
//! Python never runs here — the artifacts are HLO *text* lowered once at
//! build time; `PjRtClient::cpu()` compiles them at startup and the
//! coordinator calls [`Coder::combine`] on the request path.
//!
//! Both backends implement the same primitive — one GF linear combination
//! `out = ⊕ᵢ cᵢ·shardᵢ` — which by RS linearity (§2.2) covers encode,
//! decode, and D³'s inner-rack aggregation.

#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::gf;

/// Chooses how the byte-crunching is executed.
pub enum Coder {
    /// Pure-Rust table-driven path (always available).
    Native,
    /// PJRT CPU client executing the AOT artifacts.
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::PjrtCoder),
}

impl Coder {
    pub fn native() -> Coder {
        Coder::Native
    }

    /// Load the AOT artifacts from `dir` (default: `$D3EC_ARTIFACTS` or
    /// `./artifacts`).
    #[cfg(feature = "pjrt")]
    pub fn pjrt_from(dir: &std::path::Path) -> anyhow::Result<Coder> {
        Ok(Coder::Pjrt(pjrt::PjrtCoder::load(dir)?))
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn pjrt_from(_dir: &std::path::Path) -> anyhow::Result<Coder> {
        anyhow::bail!("built without the `pjrt` feature — rebuild with `--features pjrt`")
    }

    #[cfg(feature = "pjrt")]
    pub fn pjrt() -> anyhow::Result<Coder> {
        Ok(Coder::Pjrt(pjrt::PjrtCoder::load(&default_artifacts_dir())?))
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn pjrt() -> anyhow::Result<Coder> {
        anyhow::bail!("built without the `pjrt` feature — rebuild with `--features pjrt`")
    }

    pub fn backend_name(&self) -> &'static str {
        match self {
            Coder::Native => "native",
            #[cfg(feature = "pjrt")]
            Coder::Pjrt(_) => "pjrt",
        }
    }

    /// `out = ⊕ᵢ coeffs[i] · shards[i]` — the coding primitive.
    pub fn combine(&self, coeffs: &[u8], shards: &[&[u8]]) -> anyhow::Result<Vec<u8>> {
        assert_eq!(coeffs.len(), shards.len());
        assert!(!shards.is_empty());
        let len = shards[0].len();
        assert!(shards.iter().all(|s| s.len() == len), "ragged shards");
        match self {
            Coder::Native => Ok(gf::combine(coeffs, shards)),
            #[cfg(feature = "pjrt")]
            Coder::Pjrt(p) => p.combine(coeffs, shards),
        }
    }

    /// Encode: `parity_rows (m×k) ⊗ data (k shards)` → m parity shards.
    pub fn encode(
        &self,
        parity_rows: &crate::gf::Matrix,
        data: &[&[u8]],
    ) -> anyhow::Result<Vec<Vec<u8>>> {
        (0..parity_rows.rows())
            .map(|i| self.combine(parity_rows.row(i), data))
            .collect()
    }
}

/// `$D3EC_ARTIFACTS`, else `<manifest dir>/artifacts`.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("D3EC_ARTIFACTS") {
        return p.into();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_combine_matches_gf() {
        let coder = Coder::native();
        let a = vec![1u8, 2, 3, 4];
        let b = vec![5u8, 6, 7, 8];
        let got = coder.combine(&[3, 7], &[&a, &b]).unwrap();
        assert_eq!(got, gf::combine(&[3, 7], &[&a, &b]));
    }

    #[test]
    fn native_encode_roundtrip() {
        use crate::codes::RsCode;
        let code = RsCode::new(4, 2);
        let data: Vec<Vec<u8>> = (0..4u8).map(|i| vec![i * 17 + 1; 64]).collect();
        let refs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let coder = Coder::native();
        let parity = coder.encode(&code.parity_rows(), &refs).unwrap();
        assert_eq!(parity, code.encode(&refs));
    }
}
