//! PJRT execution of the AOT-compiled GF kernels.
//!
//! `make artifacts` lowers `gf_combine_k{k}` entry points to HLO text
//! (1 MiB-wide uint8 panels, bit-linear kernel: inputs are btab (k, 8)
//! bit tables + the data panel); this module loads `manifest.json`,
//! compiles each needed variant once on the PJRT CPU client, and streams
//! arbitrary block lengths through the fixed-width executables
//! (zero-padding the tail panel — valid because GF combination is linear
//! and 0 is absorbing).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context};

use crate::util::json::Json;

pub struct PjrtCoder {
    client: xla::PjRtClient,
    dir: PathBuf,
    /// panel width the artifacts were lowered at
    width: usize,
    /// combine_k executables, compiled lazily per fan-in k
    combine: Mutex<HashMap<usize, xla::PjRtLoadedExecutable>>,
    /// artifact file per k (from the manifest)
    combine_files: HashMap<usize, String>,
}

impl PjrtCoder {
    pub fn load(dir: &Path) -> anyhow::Result<PjrtCoder> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let manifest = Json::parse(&text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let width = manifest
            .get("width")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing width"))?;
        let mut combine_files = HashMap::new();
        for entry in manifest
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing entries"))?
        {
            let op = entry.get("op").and_then(Json::as_str).unwrap_or("");
            if op == "combine" {
                let k = entry
                    .get("k")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("combine entry missing k"))?;
                let file = entry
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("combine entry missing file"))?;
                combine_files.insert(k, file.to_string());
            }
        }
        if combine_files.is_empty() {
            bail!("no combine artifacts in manifest");
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtCoder {
            client,
            dir: dir.to_path_buf(),
            width,
            combine: Mutex::new(HashMap::new()),
            combine_files,
        })
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn supported_fanins(&self) -> Vec<usize> {
        let mut ks: Vec<usize> = self.combine_files.keys().copied().collect();
        ks.sort_unstable();
        ks
    }

    fn ensure_compiled(&self, k: usize) -> anyhow::Result<()> {
        let mut map = self.combine.lock().unwrap();
        if map.contains_key(&k) {
            return Ok(());
        }
        let file = self
            .combine_files
            .get(&k)
            .ok_or_else(|| anyhow!("no combine artifact for k={k} (have {:?})", {
                let mut v: Vec<_> = self.combine_files.keys().collect();
                v.sort();
                v
            }))?;
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        map.insert(k, exe);
        Ok(())
    }

    /// One GF linear combination through the AOT executable, panel by panel.
    pub fn combine(&self, coeffs: &[u8], shards: &[&[u8]]) -> anyhow::Result<Vec<u8>> {
        let k = coeffs.len();
        let len = shards[0].len();
        self.ensure_compiled(k)?;
        let map = self.combine.lock().unwrap();
        let exe = map.get(&k).expect("just compiled");

        // btab[i][b] = gfmul(c_i, 1 << b): the bit-linear kernel's tables
        let mut btab = vec![0u8; k * 8];
        for (i, &c) in coeffs.iter().enumerate() {
            for b in 0..8 {
                btab[i * 8 + b] = crate::gf::mul(c, 1 << b);
            }
        }
        let w = self.width;
        let mut out = vec![0u8; len];
        let mut panel = vec![0u8; k * w];
        let mut off = 0usize;
        while off < len {
            let take = (len - off).min(w);
            for (i, shard) in shards.iter().enumerate() {
                panel[i * w..i * w + take].copy_from_slice(&shard[off..off + take]);
                if take < w {
                    panel[i * w + take..(i + 1) * w].fill(0);
                }
            }
            // device buffers + raw host copy-out: one copy each way
            // (execute with Literals costs an extra literal round-trip —
            // measured 119 ms vs 86 ms per 16 MB combine, §Perf)
            let data_buf = self.client.buffer_from_host_buffer::<u8>(&panel, &[k, w], None)?;
            let btab_buf = self.client.buffer_from_host_buffer::<u8>(&btab, &[k, 8], None)?;
            let result = exe.execute_b(&[&btab_buf, &data_buf])?;
            // CopyRawToHost is unimplemented on the TFRT CPU client, so the
            // copy-out goes through one literal (the artifact's bare-array
            // root avoids the old tuple unwrap + extra literal round-trip)
            let bytes: Vec<u8> = result[0][0].to_literal_sync()?.to_vec::<u8>()?;
            out[off..off + take].copy_from_slice(&bytes[..take]);
            off += take;
        }
        Ok(out)
    }
}
