//! (k, l, g) Locally Repairable Codes (§2.3, §4.4) — Xorbas-style [9].
//!
//! Layout per stripe: `[d_0..d_{k-1}, local_0..local_{l-1}, global_0..global_{g-1}]`
//! (paper Fig 6). Local parity j is the XOR of the k/l data blocks of local
//! group j. Global parities are Cauchy rows **adjusted so they sum to the
//! all-ones row** (the Xorbas "implied parity" alignment): the XOR of all
//! global parities equals the XOR of all data, which equals the XOR of all
//! local parities. This gives exactly the paper's repair properties:
//!
//! * data / local parity: rebuilt from the k/l other blocks of its local
//!   group (coefficients all 1 — pure XOR),
//! * global parity: rebuilt from the other l + g − 1 parity blocks,
//! * arbitrary failures up to g + l recovered when information-
//!   theoretically decodable (generic solver [`LrcCode::decode_multi`]).

use crate::gf::{self, matrix::cauchy, Matrix};

#[derive(Clone, Debug)]
pub struct LrcCode {
    k: usize,
    l: usize,
    g: usize,
    /// Full generator: (k + l + g) × k over the data blocks.
    full: Matrix,
}

impl LrcCode {
    pub fn new(k: usize, l: usize, g: usize) -> LrcCode {
        assert!(l >= 1 && g >= 1, "(k,l,g)-LRC needs l,g >= 1");
        assert!(k % l == 0, "(k,l,g)-LRC requires l | k (equal local groups)");
        assert!(k + l + g <= 256, "GF(256) limited to len <= 256");
        let group = k / l;
        let mut full = Matrix::zero(k + l + g, k);
        for i in 0..k {
            full[(i, i)] = 1;
        }
        // local parity rows: XOR over the group
        for j in 0..l {
            for i in 0..group {
                full[(k + j, j * group + i)] = 1;
            }
        }
        // global parity rows: cauchy rows, last row adjusted so that the
        // rows XOR to all-ones (implied-parity alignment).
        let c = cauchy(g, k, k + 16); // offset avoids x==y with data ids
        let mut sum = vec![0u8; k];
        for j in 0..g - 1 {
            for i in 0..k {
                full[(k + l + j, i)] = c[(j, i)];
                sum[i] ^= c[(j, i)];
            }
        }
        for i in 0..k {
            full[(k + l + g - 1, i)] = 1 ^ sum[i];
        }
        LrcCode { k, l, g, full }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn l(&self) -> usize {
        self.l
    }

    pub fn g(&self) -> usize {
        self.g
    }

    pub fn len(&self) -> usize {
        self.k + self.l + self.g
    }

    /// Data blocks per local group (k / l).
    pub fn group_size(&self) -> usize {
        self.k / self.l
    }

    /// Local group of a data block index.
    pub fn group_of_data(&self, idx: usize) -> usize {
        assert!(idx < self.k);
        idx / self.group_size()
    }

    /// Parity rows (l + g) × k — encode matrix for the AOT path.
    pub fn parity_rows(&self) -> Matrix {
        let idx: Vec<usize> = (self.k..self.len()).collect();
        self.full.select_rows(&idx)
    }

    /// Generator row for any block.
    pub fn generator_row(&self, idx: usize) -> &[u8] {
        self.full.row(idx)
    }

    /// Minimal single-failure repair: `(sources, coeffs)` with
    /// `block[target] = XOR_i coeffs_i * block[sources_i]`.
    ///
    /// Matches §5.2: data/local → local group (k/l reads), global → the
    /// other l + g − 1 parity blocks.
    pub fn repair_plan(&self, target: usize) -> (Vec<usize>, Vec<u8>) {
        let (k, l) = (self.k, self.l);
        let group = self.group_size();
        assert!(target < self.len(), "target out of range");
        if target < k {
            // data block: other data of its group + the local parity
            let gid = target / group;
            let mut src: Vec<usize> = (gid * group..(gid + 1) * group)
                .filter(|&i| i != target)
                .collect();
            src.push(k + gid);
            let coeffs = vec![1u8; src.len()];
            (src, coeffs)
        } else if target < k + l {
            // local parity: its data group
            let gid = target - k;
            let src: Vec<usize> = (gid * group..(gid + 1) * group).collect();
            let coeffs = vec![1u8; src.len()];
            (src, coeffs)
        } else {
            // global parity: all locals + the other globals (implied parity)
            let mut src: Vec<usize> = (k..k + l).collect();
            src.extend((k + l..self.len()).filter(|&i| i != target));
            let coeffs = vec![1u8; src.len()];
            (src, coeffs)
        }
    }

    /// Encode: data shards (k) -> l + g parity shards, through the fused
    /// cache-blocked engine ([`gf::combine_many_into`]) on the
    /// process-wide kernel lane (DESIGN.md §12); the all-ones local rows
    /// ride its wide XOR fast path.
    pub fn encode(&self, data: &[&[u8]]) -> Vec<Vec<u8>> {
        assert_eq!(data.len(), self.k);
        let len = data.first().map_or(0, |s| s.len());
        let parity = self.parity_rows();
        (0..self.l + self.g)
            .map(|i| {
                let mut out = vec![0u8; len];
                let pairs: Vec<(u8, &[u8])> =
                    parity.row(i).iter().zip(data).map(|(&c, &s)| (c, s)).collect();
                gf::combine_many_into(&mut out, &pairs);
                out
            })
            .collect()
    }

    /// Rebuild one failed block using its minimal repair plan.
    /// `lookup` maps a stripe block index to its surviving bytes.
    pub fn repair<'a, F>(&self, target: usize, lookup: F) -> Vec<u8>
    where
        F: Fn(usize) -> &'a [u8],
    {
        let (src, coeffs) = self.repair_plan(target);
        let shards: Vec<&[u8]> = src.iter().map(|&i| lookup(i)).collect();
        gf::combine(&coeffs, &shards)
    }

    /// Generic multi-failure decode: reconstruct `targets` from `available`
    /// (any subset). Returns `None` when not information-theoretically
    /// decodable (rank < k on the needed data span).
    pub fn decode_multi(
        &self,
        available: &[usize],
        shards: &[&[u8]],
        targets: &[usize],
    ) -> Option<Vec<Vec<u8>>> {
        assert_eq!(available.len(), shards.len());
        let k = self.k;
        let width = shards.first().map_or(0, |s| s.len());
        // Solve A x = b where rows of A are generator rows of the
        // available blocks and b their byte panels; x = the data blocks.
        let a = self.full.select_rows(available);
        // Gaussian elimination with the byte panels carried along.
        let rows = available.len();
        let mut mat = a;
        let mut panels: Vec<Vec<u8>> = shards.iter().map(|s| s.to_vec()).collect();
        let mut pivot_of_col = vec![usize::MAX; k];
        let mut rank = 0usize;
        for col in 0..k {
            let Some(piv) = (rank..rows).find(|&r| mat[(r, col)] != 0) else {
                continue;
            };
            if piv != rank {
                for c in 0..k {
                    let (x, y) = (mat[(piv, c)], mat[(rank, c)]);
                    mat[(piv, c)] = y;
                    mat[(rank, c)] = x;
                }
                panels.swap(piv, rank);
            }
            let s = gf::inv(mat[(rank, col)]);
            for c in 0..k {
                mat[(rank, c)] = gf::mul(mat[(rank, c)], s);
            }
            scale_panel(&mut panels[rank], s);
            for r in 0..rows {
                if r != rank && mat[(r, col)] != 0 {
                    let f = mat[(r, col)];
                    for c in 0..k {
                        let v = gf::mul(f, mat[(rank, c)]);
                        mat[(r, c)] ^= v;
                    }
                    let (src, dst) = if r < rank {
                        let (a, b) = panels.split_at_mut(rank);
                        (&b[0], &mut a[r])
                    } else {
                        let (a, b) = panels.split_at_mut(r);
                        (&a[rank], &mut b[0])
                    };
                    gf::combine_into(dst, f, src);
                }
            }
            pivot_of_col[col] = rank;
            rank += 1;
        }
        // Recover each target: its generator row must lie in the span of
        // the pivoted columns. The panel accumulation is one fused combine
        // per target instead of a per-column accumulator sweep.
        let mut out = Vec::with_capacity(targets.len());
        for &t in targets {
            let trow = self.full.row(t);
            let mut sources: Vec<(u8, &[u8])> = Vec::new();
            for (col, &tv) in trow.iter().enumerate() {
                if tv == 0 {
                    continue;
                }
                let piv = pivot_of_col[col];
                if piv == usize::MAX {
                    return None; // needed data dimension unseen: undecodable
                }
                sources.push((tv, panels[piv].as_slice()));
            }
            let mut acc = vec![0u8; width];
            gf::combine_many_into(&mut acc, &sources);
            out.push(acc);
        }
        Some(out)
    }
}

fn scale_panel(panel: &mut [u8], s: u8) {
    if s == 1 {
        return;
    }
    gf::kernel::table(s).scale(panel);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_shards(k: usize, len: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15) | 1;
        (0..k)
            .map(|_| {
                (0..len)
                    .map(|_| {
                        s ^= s << 13;
                        s ^= s >> 7;
                        s ^= s << 17;
                        (s >> 24) as u8
                    })
                    .collect()
            })
            .collect()
    }

    fn stripe(code: &LrcCode, seed: u64) -> Vec<Vec<u8>> {
        let data = rand_shards(code.k(), 64, seed);
        let refs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let parity = code.encode(&refs);
        let mut all = data;
        all.extend(parity);
        all
    }

    #[test]
    fn global_parities_xor_to_all_data_xor() {
        for (k, l, g) in [(4, 2, 1), (6, 2, 2), (12, 2, 2), (8, 4, 2)] {
            let code = LrcCode::new(k, l, g);
            let all = stripe(&code, 9);
            let mut xor_globals = vec![0u8; 64];
            for t in k + l..code.len() {
                gf::combine_into(&mut xor_globals, 1, &all[t]);
            }
            let mut xor_data = vec![0u8; 64];
            for t in 0..k {
                gf::combine_into(&mut xor_data, 1, &all[t]);
            }
            assert_eq!(xor_globals, xor_data, "({k},{l},{g})");
        }
    }

    #[test]
    fn single_failure_repair_every_block() {
        for (k, l, g) in [(4, 2, 1), (6, 2, 2), (6, 3, 2), (12, 2, 2)] {
            let code = LrcCode::new(k, l, g);
            let all = stripe(&code, (k + l * 10 + g * 100) as u64);
            for target in 0..code.len() {
                let rebuilt = code.repair(target, |i| {
                    assert_ne!(i, target, "plan reads the failed block");
                    &all[i]
                });
                assert_eq!(rebuilt, all[target], "({k},{l},{g}) target {target}");
            }
        }
    }

    #[test]
    fn repair_read_counts_match_paper() {
        // §5.2: data/local parity read k/l blocks; global parity reads
        // l + g − 1 parity blocks.
        let code = LrcCode::new(4, 2, 1);
        for t in 0..4 {
            assert_eq!(code.repair_plan(t).0.len(), 2, "data reads k/l");
        }
        for t in 4..6 {
            assert_eq!(code.repair_plan(t).0.len(), 2, "local reads k/l");
        }
        assert_eq!(code.repair_plan(6).0.len(), 2, "global reads l+g-1");

        let wide = LrcCode::new(12, 2, 2);
        assert_eq!(wide.repair_plan(0).0.len(), 6);
        assert_eq!(wide.repair_plan(14).0.len(), 3); // l + g - 1
    }

    #[test]
    fn global_repair_reads_only_parity_blocks() {
        let code = LrcCode::new(6, 2, 2);
        for t in 8..10 {
            let (src, _) = code.repair_plan(t);
            assert!(src.iter().all(|&i| i >= 6), "global repair src {src:?}");
        }
    }

    #[test]
    fn multi_failure_decode_when_decodable() {
        let code = LrcCode::new(6, 2, 2);
        let all = stripe(&code, 77);
        // erase one data + one global (decodable: g+1 = 3 covers 2)
        let lost = [1usize, 9];
        let avail: Vec<usize> = (0..code.len()).filter(|i| !lost.contains(i)).collect();
        let shards: Vec<&[u8]> = avail.iter().map(|&i| all[i].as_slice()).collect();
        let rec = code.decode_multi(&avail, &shards, &lost).unwrap();
        assert_eq!(rec[0], all[1]);
        assert_eq!(rec[1], all[9]);
    }

    #[test]
    fn multi_failure_beyond_capability_returns_none() {
        let code = LrcCode::new(4, 2, 1);
        let all = stripe(&code, 3);
        // erase an entire local group incl. its parity: 3 failures with only
        // the global parity to help -> not decodable
        let lost = [0usize, 1, 4];
        let avail: Vec<usize> = (0..code.len()).filter(|i| !lost.contains(i)).collect();
        let shards: Vec<&[u8]> = avail.iter().map(|&i| all[i].as_slice()).collect();
        assert!(code.decode_multi(&avail, &shards, &lost).is_none());
    }

    #[test]
    fn repair_coeffs_verify_against_generator() {
        // c · G_sources == G_target row-for-row for every block.
        for (k, l, g) in [(4, 2, 1), (6, 2, 2), (12, 2, 2)] {
            let code = LrcCode::new(k, l, g);
            for t in 0..code.len() {
                let (src, coeffs) = code.repair_plan(t);
                let mut acc = vec![0u8; k];
                for (&s, &c) in src.iter().zip(&coeffs) {
                    for (a, &gv) in acc.iter_mut().zip(code.generator_row(s)) {
                        *a ^= gf::mul(c, gv);
                    }
                }
                assert_eq!(acc.as_slice(), code.generator_row(t), "({k},{l},{g}) t={t}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "l | k")]
    fn unequal_groups_rejected() {
        LrcCode::new(5, 2, 1);
    }
}
