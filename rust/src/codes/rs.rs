//! (k, m) Reed-Solomon over GF(2^8) with a systematic Cauchy generator.
//!
//! The generator's parity rows are `cauchy(m, k, offset = k)` — every
//! square submatrix of a Cauchy matrix is nonsingular, so any k of the
//! k + m blocks reconstruct the stripe (MDS). Must match
//! `python/compile/kernels/ref.py::rs_generator` so coefficients computed
//! here drive the AOT artifacts.

use crate::gf::{self, matrix::cauchy, Matrix};

#[derive(Clone, Debug)]
pub struct RsCode {
    k: usize,
    m: usize,
    /// Full systematic generator: (k+m) × k; rows 0..k are identity.
    full: Matrix,
}

impl RsCode {
    pub fn new(k: usize, m: usize) -> RsCode {
        assert!(k >= 1 && m >= 1, "(k,m)-RS needs k,m >= 1");
        assert!(k + m <= 256, "GF(256) RS limited to len <= 256");
        let parity = cauchy(m, k, k);
        let mut full = Matrix::zero(k + m, k);
        for i in 0..k {
            full[(i, i)] = 1;
        }
        for i in 0..m {
            for j in 0..k {
                full[(k + i, j)] = parity[(i, j)];
            }
        }
        RsCode { k, m, full }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn len(&self) -> usize {
        self.k + self.m
    }

    /// Parity rows of the generator, shape (m, k) — the encode matrix fed
    /// to the `gf_matmul` artifact.
    pub fn parity_rows(&self) -> Matrix {
        let idx: Vec<usize> = (self.k..self.len()).collect();
        self.full.select_rows(&idx)
    }

    /// Coefficients c with `block[target] = XOR_i c_i * block[available[i]]`
    /// for any k distinct surviving indices (RS *linearity*, §2.2).
    ///
    /// Returns `None` only if `available` violates the contract
    /// (wrong count / duplicates / contains target).
    pub fn decode_coeffs(&self, available: &[usize], target: usize) -> Option<Vec<u8>> {
        if available.len() != self.k || target >= self.len() {
            return None;
        }
        let mut seen = vec![false; self.len()];
        for &a in available {
            if a >= self.len() || seen[a] || a == target {
                return None;
            }
            seen[a] = true;
        }
        let sub = self.full.select_rows(available);
        let inv = sub.inverse().expect("Cauchy submatrix is always invertible");
        // target_row (1×k) * inv (k×k) = coefficients over `available`
        let trow = self.full.row(target);
        Some(inv_apply(trow, &inv))
    }

    /// Encode: data shards (k × len) -> m parity shards. The byte
    /// crunching runs through the fused cache-blocked engine
    /// ([`gf::combine_many_into`]) on the process-wide kernel lane
    /// (AVX2/NEON shuffles when detected — DESIGN.md §12): each parity
    /// row streams the accumulator once per L1 window, not once per
    /// data shard.
    pub fn encode(&self, data: &[&[u8]]) -> Vec<Vec<u8>> {
        assert_eq!(data.len(), self.k);
        let len = data.first().map_or(0, |s| s.len());
        let parity = self.parity_rows();
        (0..self.m)
            .map(|i| {
                let mut out = vec![0u8; len];
                let pairs: Vec<(u8, &[u8])> =
                    parity.row(i).iter().zip(data).map(|(&c, &s)| (c, s)).collect();
                gf::combine_many_into(&mut out, &pairs);
                out
            })
            .collect()
    }

    /// Reconstruct one block from exactly k survivors (fused combine).
    pub fn reconstruct(
        &self,
        available: &[usize],
        shards: &[&[u8]],
        target: usize,
    ) -> Option<Vec<u8>> {
        let coeffs = self.decode_coeffs(available, target)?;
        Some(gf::combine(&coeffs, shards))
    }
}

/// trow (1×k) × inv (k×k) worked out per-column.
fn inv_apply(trow: &[u8], inv: &Matrix) -> Vec<u8> {
    let k = trow.len();
    let mut out = vec![0u8; k];
    for (j, o) in out.iter_mut().enumerate() {
        let mut acc = 0u8;
        for (t, &tv) in trow.iter().enumerate() {
            acc ^= gf::mul(tv, inv[(t, j)]);
        }
        *o = acc;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_shards(k: usize, len: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15) | 1;
        (0..k)
            .map(|_| {
                (0..len)
                    .map(|_| {
                        s ^= s << 13;
                        s ^= s >> 7;
                        s ^= s << 17;
                        (s >> 24) as u8
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn mds_all_erasure_patterns_small_codes() {
        // For (2,1), (3,2), (4,2): every k-subset reconstructs every block.
        for (k, m) in [(2usize, 1usize), (3, 2), (4, 2)] {
            let code = RsCode::new(k, m);
            let data = rand_shards(k, 64, (k * 10 + m) as u64);
            let refs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
            let parity = code.encode(&refs);
            let mut all: Vec<&[u8]> = refs.clone();
            all.extend(parity.iter().map(|v| v.as_slice()));
            let n = k + m;
            // iterate over all k-subsets via bitmask
            for mask in 0u32..(1 << n) {
                if mask.count_ones() as usize != k {
                    continue;
                }
                let avail: Vec<usize> = (0..n).filter(|&i| mask >> i & 1 == 1).collect();
                let shards: Vec<&[u8]> = avail.iter().map(|&i| all[i]).collect();
                for target in 0..n {
                    if avail.contains(&target) {
                        continue;
                    }
                    let rec = code.reconstruct(&avail, &shards, target).unwrap();
                    assert_eq!(rec, all[target], "k={k} m={m} mask={mask:b} t={target}");
                }
            }
        }
    }

    #[test]
    fn hdfs_builtin_codes_roundtrip() {
        for (k, m) in [(2, 1), (3, 2), (6, 3), (10, 4), (12, 4)] {
            let code = RsCode::new(k, m);
            let data = rand_shards(k, 256, 42);
            let refs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
            let parity = code.encode(&refs);
            let mut all: Vec<&[u8]> = refs.clone();
            all.extend(parity.iter().map(|v| v.as_slice()));
            // erase the first m blocks, recover each from the rest
            let avail: Vec<usize> = (m..k + m).collect();
            let shards: Vec<&[u8]> = avail.iter().map(|&i| all[i]).collect();
            for target in 0..m {
                let rec = code.reconstruct(&avail, &shards, target).unwrap();
                assert_eq!(rec, all[target], "({k},{m}) target {target}");
            }
        }
    }

    #[test]
    fn encode_matches_per_byte_reference() {
        // kernel cross-check: the slice-table path behind gf::combine must
        // agree with a naive per-byte gf::mul accumulation
        let code = RsCode::new(6, 3);
        let data = rand_shards(6, 333, 21);
        let refs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let parity = code.encode(&refs);
        let rows = code.parity_rows();
        for (i, p) in parity.iter().enumerate() {
            let mut want = vec![0u8; 333];
            for (j, shard) in refs.iter().enumerate() {
                let c = rows.row(i)[j];
                for (w, &s) in want.iter_mut().zip(*shard) {
                    *w ^= gf::mul(c, s);
                }
            }
            assert_eq!(p, &want, "parity row {i}");
        }
    }

    #[test]
    fn decode_coeffs_rejects_bad_input() {
        let code = RsCode::new(3, 2);
        assert!(code.decode_coeffs(&[0, 1], 4).is_none()); // too few
        assert!(code.decode_coeffs(&[0, 1, 1], 4).is_none()); // dup
        assert!(code.decode_coeffs(&[0, 1, 4], 4).is_none()); // contains target
        assert!(code.decode_coeffs(&[0, 1, 9], 4).is_none()); // out of range
        assert!(code.decode_coeffs(&[0, 1, 2], 9).is_none()); // target oob
    }

    #[test]
    fn coefficients_for_data_from_data_are_identityish() {
        // reconstructing a data block when all of data survives: the
        // coefficient vector selects exactly that block.
        let code = RsCode::new(4, 2);
        let avail = vec![0, 1, 2, 3];
        let c = code.decode_coeffs(&avail, 4).unwrap(); // parity from data
        // parity row 0 of the cauchy generator
        let pr = code.parity_rows();
        assert_eq!(&c, pr.row(0));
    }

    #[test]
    fn partial_aggregation_identity() {
        // The D³ inner-rack aggregation (§3.2.1): splitting the coefficient
        // set by rack and XOR-ing partial sums equals the direct combine.
        let code = RsCode::new(6, 3);
        let data = rand_shards(6, 128, 7);
        let refs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let parity = code.encode(&refs);
        let mut all: Vec<&[u8]> = refs.clone();
        all.extend(parity.iter().map(|v| v.as_slice()));

        let avail = vec![1, 2, 3, 4, 5, 6];
        let shards: Vec<&[u8]> = avail.iter().map(|&i| all[i]).collect();
        let c = code.decode_coeffs(&avail, 0).unwrap();
        let direct = gf::combine(&c, &shards);

        let agg_a = gf::combine(&c[..3], &shards[..3]);
        let agg_b = gf::combine(&c[3..], &shards[3..]);
        let via = gf::combine(&[1, 1], &[&agg_a, &agg_b]);
        assert_eq!(direct, via);
        assert_eq!(direct, all[0]);
    }
}
