//! Erasure codes: Reed-Solomon (§2.2) and Locally Repairable Codes (§2.3).
//!
//! Everything placement/recovery needs from a code is captured by
//! [`CodeSpec`] (shape) plus the concrete coefficient machinery in
//! [`rs::RsCode`] / [`lrc::LrcCode`]. Block indices within a stripe are
//! `0..len`: data first, then parity (for LRC: data, local parities,
//! global parities — matching paper Fig 6).

pub mod lrc;
pub mod rs;

pub use lrc::LrcCode;
pub use rs::RsCode;

/// The role a block plays within its stripe.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockKind {
    Data,
    /// LRC local parity for group `group`.
    LocalParity { group: usize },
    /// RS parity / LRC global parity.
    GlobalParity,
}

/// Code shape, serializable for configs and the CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodeSpec {
    /// (k, m)-RS: k data, m parity, MDS.
    Rs { k: usize, m: usize },
    /// (k, l, g)-LRC: k data in l local groups (XOR local parity each)
    /// plus g global parities.
    Lrc { k: usize, l: usize, g: usize },
}

impl CodeSpec {
    /// Stripe size len = number of blocks per stripe.
    pub fn len(&self) -> usize {
        match *self {
            CodeSpec::Rs { k, m } => k + m,
            CodeSpec::Lrc { k, l, g } => k + l + g,
        }
    }

    pub fn k(&self) -> usize {
        match *self {
            CodeSpec::Rs { k, .. } | CodeSpec::Lrc { k, .. } => k,
        }
    }

    /// Number of parity blocks.
    pub fn parity(&self) -> usize {
        match *self {
            CodeSpec::Rs { m, .. } => m,
            CodeSpec::Lrc { l, g, .. } => l + g,
        }
    }

    /// Max blocks of one stripe a rack may hold while tolerating a single
    /// rack failure: m for RS (§4.1); 1 for LRC (maximum rack-level fault
    /// tolerance, §4.4 basic rules).
    pub fn rack_limit(&self) -> usize {
        match *self {
            CodeSpec::Rs { m, .. } => m,
            CodeSpec::Lrc { .. } => 1,
        }
    }

    pub fn kind(&self, idx: usize) -> BlockKind {
        assert!(idx < self.len(), "block index out of range");
        match *self {
            CodeSpec::Rs { k, .. } => {
                if idx < k {
                    BlockKind::Data
                } else {
                    BlockKind::GlobalParity
                }
            }
            CodeSpec::Lrc { k, l, .. } => {
                if idx < k {
                    BlockKind::Data
                } else if idx < k + l {
                    BlockKind::LocalParity { group: idx - k }
                } else {
                    BlockKind::GlobalParity
                }
            }
        }
    }

    pub fn is_lrc(&self) -> bool {
        matches!(self, CodeSpec::Lrc { .. })
    }

    /// Human-readable name, e.g. "(6,3)-RS" or "(4,2,1)-LRC".
    pub fn name(&self) -> String {
        match *self {
            CodeSpec::Rs { k, m } => format!("({k},{m})-RS"),
            CodeSpec::Lrc { k, l, g } => format!("({k},{l},{g})-LRC"),
        }
    }

    /// Parse "rs-6-3" / "lrc-4-2-1" (CLI format).
    pub fn parse(s: &str) -> Option<CodeSpec> {
        let parts: Vec<&str> = s.split('-').collect();
        match parts.as_slice() {
            ["rs", k, m] => Some(CodeSpec::Rs { k: k.parse().ok()?, m: m.parse().ok()? }),
            ["lrc", k, l, g] => Some(CodeSpec::Lrc {
                k: k.parse().ok()?,
                l: l.parse().ok()?,
                g: g.parse().ok()?,
            }),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_shapes() {
        let rs = CodeSpec::Rs { k: 6, m: 3 };
        assert_eq!(rs.len(), 9);
        assert_eq!(rs.rack_limit(), 3);
        assert_eq!(rs.kind(5), BlockKind::Data);
        assert_eq!(rs.kind(6), BlockKind::GlobalParity);

        let lrc = CodeSpec::Lrc { k: 4, l: 2, g: 1 };
        assert_eq!(lrc.len(), 7);
        assert_eq!(lrc.rack_limit(), 1);
        assert_eq!(lrc.kind(3), BlockKind::Data);
        assert_eq!(lrc.kind(4), BlockKind::LocalParity { group: 0 });
        assert_eq!(lrc.kind(5), BlockKind::LocalParity { group: 1 });
        assert_eq!(lrc.kind(6), BlockKind::GlobalParity);
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(CodeSpec::parse("rs-6-3"), Some(CodeSpec::Rs { k: 6, m: 3 }));
        assert_eq!(
            CodeSpec::parse("lrc-4-2-1"),
            Some(CodeSpec::Lrc { k: 4, l: 2, g: 1 })
        );
        assert_eq!(CodeSpec::parse("nope"), None);
        assert_eq!(CodeSpec::parse("rs-x-3"), None);
    }

    #[test]
    fn names() {
        assert_eq!(CodeSpec::Rs { k: 2, m: 1 }.name(), "(2,1)-RS");
        assert_eq!(CodeSpec::Lrc { k: 4, l: 2, g: 1 }.name(), "(4,2,1)-LRC");
    }
}
